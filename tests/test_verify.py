"""weldcheck: golden broken programs, mutation recall, pipeline wiring.

Three layers:

1. a hand-broken golden program per diagnostic code — each must be
   caught with exactly that code (and a clean twin must not be);
2. the seeded mutation harness over real planned programs captured from
   weldrel joins / group-bys (>=95% catch rate, offender named);
3. integration — a sabotaged optimizer pass raises ``WeldVerifyError``
   naming the pass; ``explain()`` grows a ``-- verify --`` section; the
   full corpus verifies clean end to end.
"""
import copy
from dataclasses import replace

import numpy as np
import pytest

from repro.core import check, ir, recovery, wtypes as wt
from repro.core.check import mutate
from repro.core.check.diagnostics import CODES
from repro.core.errors import WeldVerifyError
from repro.frames import weldrel


# ---------------------------------------------------------------------------
# builders for small well-typed programs
# ---------------------------------------------------------------------------

XS = ir.Ident("xs", wt.Vec(wt.F64))


def sum_loop(op="+"):
    """result(for([xs], merger[f64,op], merge))"""
    bty = wt.Merger(wt.F64, op)
    b, i, e = (ir.Ident("b", bty), ir.Ident("i", wt.I64),
               ir.Ident("e", wt.F64))
    return ir.Result(ir.For(
        (ir.Iter(XS),), ir.NewBuilder(bty),
        ir.Lambda((b, i, e), ir.Merge(b, e))))


def dict_loop(cap=16):
    """group-by-style dictmerger build with a capacity literal."""
    bty = wt.DictMerger(wt.I64, wt.F64, "+")
    b, i, e = (ir.Ident("b", bty), ir.Ident("i", wt.I64),
               ir.Ident("e", wt.F64))
    return ir.Result(ir.For(
        (ir.Iter(XS),), ir.NewBuilder(bty, arg=ir.Literal(cap, wt.I64)),
        ir.Lambda((b, i, e),
                  ir.Merge(b, ir.MakeStruct((ir.Cast(e, wt.I64), e))))))


def codes_of(e, env=None, **kw):
    return sorted({d.code for d in check.verify(e, env=env, **kw)})


def hinted_vec_loop(hint, n_elems=4):
    """Map-style loop over a constant-length vector into a hinted
    vecbuilder — the weldbound-derived row count is the constant
    ``n_elems``, so the bounds lint can compare declared sizes without
    any input shapes."""
    vbt = wt.VecBuilder(wt.F64)
    b, i, e = (ir.Ident("b", vbt), ir.Ident("i", wt.I64),
               ir.Ident("e", wt.F64))
    mv = ir.MakeVec(tuple(ir.Literal(float(k), wt.F64)
                          for k in range(n_elems)), wt.F64)
    return ir.Result(ir.For(
        (ir.Iter(mv),),
        ir.NewBuilder(vbt, size_hint=ir.Literal(hint, wt.I64)),
        ir.Lambda((b, i, e), ir.Merge(b, e))))


def corrupt_op(bty, op="-"):
    """A merger-family type with a non-commutative op, built the only
    way one can exist: by bypassing the constructor's guard."""
    bad = copy.copy(bty)
    object.__setattr__(bad, "op", op)
    return bad


# ---------------------------------------------------------------------------
# golden corpus: one hand-broken program per diagnostic code
# ---------------------------------------------------------------------------


def golden_cases():
    bty = wt.Merger(wt.F64, "+")
    b = ir.Ident("b", bty)
    i, e = ir.Ident("i", wt.I64), ir.Ident("e", wt.F64)

    yield "WV101", ir.BinOp("+", ir.Literal(1, wt.I64),
                            ir.Literal(1.0, wt.F64))
    # annotated i64 but let-bound as f64
    yield "WV102", ir.Let("v", ir.Literal(1.0, wt.F64),
                          ir.BinOp("+", ir.Ident("v", wt.I64),
                                   ir.Ident("v", wt.I64)))
    yield "WV103", ir.KernelCall("no_such_kernel", (XS,), wt.Vec(wt.F64))
    # merger initialised with a vector
    yield "WV104", ir.Result(ir.NewBuilder(bty, arg=XS))
    yield "WV201", ir.Let("bb", ir.NewBuilder(bty),
                          ir.Literal(1.0, wt.F64))
    # merged twice in sequence: two uses on the one path
    yield "WV202", ir.Let(
        "bb", ir.NewBuilder(bty),
        ir.Result(ir.Merge(ir.Ident("bb", bty),
                           ir.Result(ir.Merge(ir.Ident("bb", bty),
                                              ir.Literal(1.0, wt.F64))))))
    # result() then merge into the same builder again
    yield "WV203", ir.Let(
        "bb", ir.NewBuilder(bty),
        ir.Let("x", ir.Result(ir.Ident("bb", bty)),
               ir.Result(ir.Merge(ir.Ident("bb", bty),
                                  ir.Ident("x", wt.F64)))))
    # free builder captured by a loop body: merged once per iteration
    yield "WV204", ir.Let(
        "bb", ir.NewBuilder(bty),
        ir.Result(ir.For(
            (ir.Iter(XS),), ir.NewBuilder(bty),
            ir.Lambda((b, i, e),
                      ir.Merge(b, ir.Result(
                          ir.Merge(ir.Ident("bb", bty), e)))))))
    # consumed only on the true branch
    yield "WV205", ir.Let(
        "bb", ir.NewBuilder(bty),
        ir.If(ir.Literal(True, wt.Bool),
              ir.Result(ir.Merge(ir.Ident("bb", bty),
                                 ir.Literal(1.0, wt.F64))),
              ir.Literal(0.0, wt.F64)))
    # select evaluates both arms: the builder is consumed twice
    yield "WV206", ir.Let(
        "bb", ir.NewBuilder(bty),
        ir.Result(ir.Select(
            ir.Literal(True, wt.Bool),
            ir.Merge(ir.Ident("bb", bty), ir.Literal(1.0, wt.F64)),
            ir.Merge(ir.Ident("bb", bty), ir.Literal(2.0, wt.F64)))))

    bad_merger = corrupt_op(bty)
    bb = ir.Ident("b", bad_merger)
    yield "WV301", ir.Result(ir.For(
        (ir.Iter(XS),), ir.NewBuilder(bad_merger),
        ir.Lambda((bb, i, e), ir.Merge(bb, e))))
    # loop body observes its own builder mid-build
    yield "WV302", ir.Result(ir.For(
        (ir.Iter(XS),), ir.NewBuilder(bty),
        ir.Lambda((b, i, e), ir.Merge(b, ir.Result(b)))))
    # data-dependent scatter index under a non-commutative combine
    vm = corrupt_op(wt.VecMerger(wt.F64, "+"))
    vb = ir.Ident("b", vm)
    yield "WV303", ir.Result(ir.For(
        (ir.Iter(XS),), ir.NewBuilder(vm, arg=XS),
        ir.Lambda((vb, i, e),
                  ir.Merge(vb, ir.MakeStruct((ir.Cast(e, wt.I64), e))))))

    yield "WV401", dict_mutant_capacity(0)
    yield "WV402", ir.KernelCall(
        "hash_probe", (XS,), wt.Vec(wt.F64), params=(("k", -4),))
    yield "WV403", ir.Result(ir.For(
        (ir.Iter(XS),),
        ir.NewBuilder(wt.VecBuilder(wt.F64),
                      size_hint=ir.Literal(-8, wt.I64)),
        ir.Lambda((ir.Ident("b", wt.VecBuilder(wt.F64)), i, e),
                  ir.Merge(ir.Ident("b", wt.VecBuilder(wt.F64)), e))))

    # weldbound contradictions: a 4-element map merges exactly 4 rows
    yield "WV501", hinted_vec_loop(hint=2)       # provable truncation
    yield "WV502", hinted_vec_loop(hint=500)     # provable waste
    # certificate 8GB vs a 1KB limit (memory_limit via VERIFY_KW)
    yield "WV503", hinted_vec_loop(hint=10 ** 9)


def dict_mutant_capacity(cap):
    good = dict_loop()
    nb = next(n for n in ir.walk(good) if isinstance(n, ir.NewBuilder))
    return mutate._replace_node(
        good, nb, replace(nb, arg=ir.Literal(cap, wt.I64)))


#: extra verify() kwargs a golden case needs to be catchable
VERIFY_KW = {"WV503": {"memory_limit": 1024}}


@pytest.mark.parametrize("code,prog",
                         list(golden_cases()),
                         ids=[c for c, _ in golden_cases()])
def test_golden_broken_program_caught(code, prog):
    got = codes_of(prog, **VERIFY_KW.get(code, {}))
    assert code in got, f"expected {code} ({CODES[code][0]}), got {got}"


def test_golden_codes_cover_registry():
    """Every registered code except the differential-only WV404 has a
    golden broken program."""
    covered = {c for c, _ in golden_cases()} | {"WV404"}
    assert covered == set(CODES)


def test_clean_programs_verify_clean():
    assert codes_of(sum_loop()) == []
    assert codes_of(dict_loop()) == []


def test_diagnostic_renders_anchor_and_snippet():
    prog = dict_mutant_capacity(0)
    diags = check.verify(prog)
    assert diags and diags[0].code == "WV401"
    msg = diags[0].render(prog)
    assert "#n" in msg and "dictmerger" in msg and "bad-capacity" in msg


def test_checkpoint_raises_typed_error_naming_phase():
    check.set_enabled(True)
    try:
        with pytest.raises(WeldVerifyError) as exc:
            check.checkpoint("pass.fusion", dict_mutant_capacity(0))
    finally:
        check.set_enabled(None)
    err = exc.value
    assert err.phase == "pass.fusion"
    assert "WV401" in err.codes
    assert "pass.fusion" in str(err) and ">>>" in str(err)


def test_verify_rewrite_rejects_shrinking_regrow():
    before, after = dict_loop(16), dict_loop(8)
    check.set_enabled(True)
    try:
        with pytest.raises(WeldVerifyError) as exc:
            check.verify_rewrite("recovery.regrow", before, after)
        assert "WV404" in exc.value.codes
        # a genuine regrow passes
        grown, n = recovery.regrow_capacities(before, 2)
        assert n == 1
        check.verify_rewrite("recovery.regrow", before, grown)
    finally:
        check.set_enabled(None)


# ---------------------------------------------------------------------------
# mutation harness over real planned programs
# ---------------------------------------------------------------------------


def _captured_programs():
    """Planned IR (+ bound input shapes) from real weldrel pipelines: a
    hash join, a group-by aggregate, and inner/left m:n joins
    (GroupBuilder expansion — the left one carries the nonzero derived
    lower bound the WV501 mutator targets)."""
    rng = np.random.RandomState(7)
    n = 64
    progs, shapes = [], []

    def cap(st):
        progs.append(st["plan.ir"])
        shapes.append(st["plan.inputs"][2])

    left = weldrel.Table({"k": rng.randint(0, 8, n).astype(np.int64),
                          "lv": rng.rand(n)})
    right1 = weldrel.Table({"k": np.arange(8, dtype=np.int64),
                            "rv": rng.rand(8)})
    st = {}
    weldrel.Query(left).join(right1, on="k", how="inner",
                             collect_stats=st)
    cap(st)

    st = {}
    weldrel.Query(left).group_agg(
        [left.col("k")], {"s": (left.col("lv"), "+")}, collect_stats=st)
    cap(st)

    rightmn = weldrel.Table({"k": rng.randint(0, 4, 16).astype(np.int64),
                             "rv": rng.rand(16)})
    st = {}
    weldrel.Query(left).join(rightmn, on="k", how="inner",
                             collect_stats=st)
    cap(st)

    st = {}
    weldrel.Query(left).join(rightmn, on="k", how="left",
                             collect_stats=st)
    cap(st)
    return progs, shapes


def test_mutation_harness_recall():
    progs, shapes = _captured_programs()
    score = mutate.run_mutations(progs, seed=2026, rounds=3,
                                 shapes=shapes)
    assert score.applied >= 30
    assert score.rate >= 0.95, (
        f"verifier caught {score.caught}/{score.applied} mutants "
        f"({score.rate:.0%}); misses: {score.misses}"
    )


def test_captured_corpus_verifies_clean():
    progs, shapes = _captured_programs()
    for prog, shp in zip(progs, shapes):
        assert codes_of(prog, shapes=shp) == [], \
            "planned pipeline IR must be clean (bounds lint included)"


# ---------------------------------------------------------------------------
# pipeline integration
# ---------------------------------------------------------------------------


def test_sabotaged_pass_is_caught_and_named(monkeypatch):
    """A pass that corrupts the program mid-fixpoint must be blamed by
    name, before planning or codegen ever sees the broken IR."""
    from repro.core import passes as P

    def evil_cse(e, stats):
        # drop every Result wrapper: type/linearity carnage
        return P.ir.postorder_map(
            e, lambda n: n.builder if isinstance(n, P.ir.Result) else n)

    monkeypatch.setitem(P._PASS_FNS, "cse", evil_cse)
    check.set_enabled(True)
    try:
        with pytest.raises(WeldVerifyError) as exc:
            P.optimize(dict_loop())
    finally:
        check.set_enabled(None)
    assert exc.value.phase == "pass.cse"


def test_explain_has_verify_section():
    from repro.core import runtime

    runtime.clear_cache()
    check.set_enabled(True)
    try:
        rng = np.random.RandomState(0)
        t = weldrel.Table({"k": rng.randint(0, 4, 32).astype(np.int64),
                           "lv": rng.rand(32)})
        rep = weldrel.Query(t).explain().group_agg(
            [t.col("k")], {"s": (t.col("lv"), "+")})
        text = rep.render()
    finally:
        check.set_enabled(None)
    assert "-- verify --" in text
    assert "weldcheck" in text and "checkpoints clean" in text
    assert "pass.cse" in text and "kernelplan" in text
    assert rep.stats["verify.runs"] > 0
    assert rep.stats["verify.ms"] >= 0


def test_verify_disabled_is_a_noop():
    check.set_enabled(False)
    try:
        stats = {}
        check.checkpoint("pass.fusion", dict_mutant_capacity(0),
                         stats=stats)
        assert stats == {}
    finally:
        check.set_enabled(None)
