"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle over
shape/dtype sweeps, plus hypothesis sweeps for the reductions."""
import numpy as np
import pytest
import jax

# the IR runtime enables x64 globally on import; do the same here so the
# f64 sweeps keep their dtype when this module runs first/alone.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
try:  # hypothesis is an optional extra: sweeps run everywhere, the
    # property tests only where it is installed.
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

    def given(**kw):  # no-op decorator: the test below is skipped
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(**kw):
        def deco(fn):
            return fn
        return deco

    class st:  # noqa: N801 - mirrors the hypothesis strategies namespace
        @staticmethod
        def integers(*a, **kw):
            return None

from repro.kernels import ops, ref
from repro.kernels import filter_reduce, flash_attention, fused_adamw
from repro.kernels import segment_reduce, tiled_matmul

rng = np.random.RandomState(7)


# ---------------------------------------------------------------------------
# filter_reduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 100, 8 * 1024, 8 * 1024 + 3, 40_000])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_filter_reduce_sum_sweep(n, dtype):
    x = rng.rand(n).astype(dtype)
    pred = rng.rand(n) > 0.5
    got = filter_reduce.filter_reduce_sum(
        jnp.asarray(x), jnp.asarray(pred), interpret=True
    )
    want = ref.filter_reduce_sum(jnp.asarray(x), jnp.asarray(pred))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5 if dtype == np.float32 else 1e-12)


@pytest.mark.parametrize("k", [1, 3])
@pytest.mark.parametrize("n", [1000, 9000])
def test_filter_reduce_q6_sweep(k, n):
    cols = rng.rand(k, n).astype(np.float32)
    lo = np.quantile(cols, 0.2, axis=1).astype(np.float32)
    hi = np.quantile(cols, 0.8, axis=1).astype(np.float32)
    val = rng.rand(n).astype(np.float32)
    got = filter_reduce.filter_reduce_q6(
        jnp.asarray(cols), jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(val),
        interpret=True,
    )
    want = ref.filter_reduce_q6(jnp.asarray(cols), jnp.asarray(lo),
                                jnp.asarray(hi), jnp.asarray(val))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 1 << 30))
def test_filter_reduce_property(n, seed):
    r = np.random.RandomState(seed)
    x = r.randn(n).astype(np.float32)
    pred = r.rand(n) > r.rand()
    got = filter_reduce.filter_reduce_sum(jnp.asarray(x), jnp.asarray(pred),
                                          interpret=True, block=256)
    np.testing.assert_allclose(np.asarray(got), x[pred].sum(), atol=1e-3)


# ---------------------------------------------------------------------------
# segment_reduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k", [(100, 4), (512, 64), (2048, 128), (700, 13)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_segment_sum_sweep(n, k, dtype):
    seg = rng.randint(0, k, n).astype(np.int32)
    vals = rng.rand(n).astype(dtype)
    got = segment_reduce.segment_sum(jnp.asarray(seg), jnp.asarray(vals), k,
                                     interpret=True)
    want = ref.segment_sum(jnp.asarray(seg), jnp.asarray(vals), k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


@pytest.mark.parametrize("n,k,d", [(64, 4, 8), (300, 16, 32), (512, 8, 128)])
def test_segment_sum_vectors_sweep(n, k, d):
    seg = rng.randint(0, k, n).astype(np.int32)
    vals = rng.rand(n, d).astype(np.float32)
    got = segment_reduce.segment_sum_vectors(
        jnp.asarray(seg), jnp.asarray(vals), k, interpret=True, block=128
    )
    want = ref.segment_sum_vectors(jnp.asarray(seg), jnp.asarray(vals), k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


# ---------------------------------------------------------------------------
# fused_adamw
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [10, 16 * 1024, 16 * 1024 + 7, 50_000])
def test_fused_adamw_sweep(n):
    p = rng.randn(n).astype(np.float32)
    g = rng.randn(n).astype(np.float32) * 0.1
    m = rng.randn(n).astype(np.float32) * 0.01
    v = np.abs(rng.randn(n)).astype(np.float32) * 0.001
    got = fused_adamw.adamw_update(
        jnp.asarray(p), jnp.asarray(g), jnp.asarray(m), jnp.asarray(v),
        3e-4, 5.0, interpret=True,
    )
    want = ref.adamw_update(jnp.asarray(p), jnp.asarray(g), jnp.asarray(m),
                            jnp.asarray(v), 3e-4, 5.0)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=1e-7)


def test_fused_adamw_steps_match_sequence():
    """Multiple consecutive kernel steps track the oracle trajectory."""
    n = 1000
    p = rng.randn(n).astype(np.float32)
    g0 = rng.randn(n).astype(np.float32)
    m = np.zeros(n, np.float32)
    v = np.zeros(n, np.float32)
    pk, mk, vk = jnp.asarray(p), jnp.asarray(m), jnp.asarray(v)
    pr, mr, vr = jnp.asarray(p), jnp.asarray(m), jnp.asarray(v)
    for t in range(1, 4):
        g = jnp.asarray(g0 * t)
        pk, mk, vk = fused_adamw.adamw_update(pk, g, mk, vk, 1e-3, float(t),
                                              interpret=True)
        pr, mr, vr = ref.adamw_update(pr, g, mr, vr, 1e-3, float(t))
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-5,
                               atol=1e-7)


# ---------------------------------------------------------------------------
# tiled_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [
    (8, 8, 8), (256, 512, 256), (100, 300, 50), (257, 513, 129),
])
def test_tiled_matmul_sweep(m, k, n):
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    got = tiled_matmul.tiled_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=64, bn=64, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,sq,skv,d,group,causal", [
    (2, 64, 64, 32, 1, True),
    (4, 128, 128, 64, 2, True),
    (2, 64, 256, 32, 1, True),    # decode-ish: q shorter than kv
    (2, 100, 100, 32, 1, False),  # non-causal + padding path
    (8, 96, 96, 16, 4, True),     # GQA group=4
])
def test_flash_attention_sweep(h, sq, skv, d, group, causal):
    q = rng.randn(h, sq, d).astype(np.float32) * 0.3
    k = rng.randn(h // group, skv, d).astype(np.float32) * 0.3
    v = rng.randn(h // group, skv, d).astype(np.float32)
    got = flash_attention.flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, group=group, bq=32, bk=32, interpret=True,
    )
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=causal, group=group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_chunked_attention_matches_dense():
    q = rng.randn(4, 200, 32).astype(np.float32) * 0.5
    k = rng.randn(2, 200, 32).astype(np.float32) * 0.5
    v = rng.randn(2, 200, 32).astype(np.float32)
    got = ref.chunked_attention(jnp.asarray(q), jnp.asarray(k),
                                jnp.asarray(v), causal=True, group=2,
                                chunk=64)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=True, group=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------


def test_ops_impl_dispatch():
    x = jnp.asarray(rng.rand(1000).astype(np.float32))
    pred = jnp.asarray(rng.rand(1000) > 0.5)
    a = ops.filter_reduce_sum(x, pred, impl="ref")
    b = ops.filter_reduce_sum(x, pred, impl="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_ops_default_impl_flip():
    x = jnp.asarray(rng.rand(128).astype(np.float32))
    pred = jnp.asarray(np.ones(128, bool))
    ops.set_default_impl("ref")
    a = ops.filter_reduce_sum(x, pred)
    ops.set_default_impl("interpret")
    b = ops.filter_reduce_sum(x, pred)
    ops.set_default_impl("ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
