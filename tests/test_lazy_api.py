"""Runtime API tests (paper §4, Table 2): DAG stitching, evaluation
points, caching, memory limits, lifecycle."""
import numpy as np
import pytest

from repro.core import ir, macros as M, wtypes as wt
from repro.core.lazy import (
    Evaluate, FreeWeldObject, GetObjectType, NewWeldObject, build_program,
)
from repro.core import runtime
from repro.core.backend.jaxgen import WeldMemoryError


def _data(arr):
    return NewWeldObject(np.asarray(arr), None)


def _id(o):
    return ir.Ident(o.obj_id, o.weld_type())


def test_object_types():
    d = _data(np.arange(4, dtype=np.int64))
    assert GetObjectType(d) == wt.Vec(wt.I64)
    d2 = _data(np.float32(2.5))
    assert GetObjectType(d2) in (wt.F32, wt.F64)


def test_undeclared_dep_rejected():
    d = _data(np.arange(4, dtype=np.int64))
    rogue = ir.Ident("not_declared", wt.Vec(wt.I64))
    with pytest.raises(ValueError):
        NewWeldObject([d], M.reduce_(rogue, "+"))


def test_dag_shared_dependency_evaluated_once():
    d = _data(np.arange(10, dtype=np.int64))
    shared = NewWeldObject([d], M.map_(_id(d), lambda x: ir.BinOp("*", x, M.lit(2))))
    a = NewWeldObject([shared], M.reduce_(_id(shared), "+"))
    b = NewWeldObject([shared], M.reduce_(_id(shared), "max"))
    both = NewWeldObject([a, b], ir.MakeStruct((_id(a), _id(b))))
    prog = build_program(both)
    # shared appears once in the stitched let-chain
    lets = [n for n in ir.walk(prog.expr) if isinstance(n, ir.Let)]
    assert len([l for l in lets if l.name == shared.obj_id]) == 1
    stats = {}
    res = Evaluate(both, collect_stats=stats)
    assert res.value == (90, 18)
    assert stats["loops.after"] == 1  # one pass over the data for everything


def test_compile_cache_hit():
    runtime.clear_cache()
    d = _data(np.arange(8, dtype=np.int64))
    mk = lambda: NewWeldObject([d], M.reduce_(_id(d), "+"))
    r1 = Evaluate(mk())
    assert not r1.from_cache and r1.compile_ms > 0
    r2 = Evaluate(mk())
    assert r2.from_cache
    assert r1.value == r2.value == 28


def test_memory_limit_enforced():
    runtime.clear_cache()
    d = _data(np.arange(1_000, dtype=np.int64))
    # map materializes ~8KB; 1KB limit must trip
    obj = NewWeldObject([d], M.map_(_id(d), lambda x: ir.BinOp("+", x, M.lit(1))))
    with pytest.raises(WeldMemoryError):
        Evaluate(obj, memory_limit=1024, optimize=True)
    ok = Evaluate(obj, memory_limit=1 << 20)
    assert ok.value[-1] == 1000


def test_free_object_lifecycle():
    d = _data(np.arange(4, dtype=np.int64))
    obj = NewWeldObject([d], M.reduce_(_id(d), "+"))
    FreeWeldObject(obj)
    with pytest.raises(RuntimeError):
        Evaluate(obj)


def test_result_free():
    d = _data(np.arange(4, dtype=np.int64))
    obj = NewWeldObject([d], M.reduce_(_id(d), "+"))
    res = Evaluate(obj)
    res.free()
    assert res.value is None


def test_unoptimized_matches_optimized():
    d = _data(np.arange(32, dtype=np.int64))
    f = NewWeldObject([d], M.filter_(_id(d), lambda x: ir.BinOp(">", x, M.lit(10))))
    s = NewWeldObject([f], M.reduce_(_id(f), "+"))
    v1 = Evaluate(s, optimize=True).value
    v2 = Evaluate(s, optimize=False).value
    assert v1 == v2 == sum(range(11, 32))


def test_struct_output_decode():
    d = _data(np.array([1.5, 2.5], dtype=np.float64))
    a = NewWeldObject([d], M.reduce_(_id(d), "+"))
    b = NewWeldObject([d], M.reduce_(_id(d), "max"))
    both = NewWeldObject([a, b], ir.MakeStruct((_id(a), _id(b))))
    out = Evaluate(both).value
    assert out == (4.0, 2.5)


def test_evaluation_is_lazy_until_forced():
    """No computation happens at graph-build time."""
    d = _data(np.arange(4, dtype=np.int64))
    # an expression that would fail at runtime if evaluated (div by zero is
    # fine in XLA; use memory limit as the observable instead)
    obj = NewWeldObject([d], M.map_(_id(d), lambda x: ir.BinOp("+", x, M.lit(1))))
    assert obj.weld_type() == wt.Vec(wt.I64)  # type known without running
    # nothing cached/executed yet for this structure with this limit
    runtime.clear_cache()
    assert runtime.cache_size() == 0
