"""weldserve: AOT staging, the single-flight LRU compile cache, the
concurrent QueryServer, ledger calibration, and the cache/ledger
lifecycle bugfixes (stale-key refile leak, admission-contract degrade,
torn-write ledger reads)."""
import json
import os
import threading

import numpy as np
import pytest

from repro.core import obs, runtime
from repro.core.analysis import bounds as _bounds
from repro.core.errors import ResourceError
from repro.core.kernelplan import autotune, calibrate, quarantine
from repro.core.lazy import Evaluate
from repro.core.obs import ledger
from repro.core.serve import QueryServer
from repro.frames import weldnp
from repro.frames.weldrel import Query, Table, _host


@pytest.fixture(autouse=True)
def hermetic(tmp_path, monkeypatch):
    """Fresh caches + ledger + health file per test: no cross-test
    tuning state, no calibration bleed from a developer's real ledger."""
    monkeypatch.setenv("WELD_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("WELD_COST_LEDGER", str(tmp_path / "ledger.jsonl"))
    monkeypatch.setenv(quarantine.ENV_FILE, str(tmp_path / "health.json"))
    quarantine.clear(disk=False)
    autotune.clear_cache(disk=False)
    calibrate.invalidate()
    runtime.clear_cache()
    yield
    runtime.clear_cache()
    calibrate.invalidate()
    autotune.clear_cache(disk=False)
    quarantine.clear(disk=False)


def _tables(n=20000, k=100, seed=0):
    rng = np.random.default_rng(seed)
    probe = {"k": rng.integers(0, k, n), "x": rng.normal(size=n)}
    build = {"k": np.arange(k), "w": rng.normal(size=k)}
    return probe, build


def _oracle_join(probe, build, **kw):
    return Query(Table(dict(probe), eager=True)).join(
        Table(dict(build), eager=True), **kw)


def _assert_tables_equal(got: Table, want: Table):
    assert sorted(got.cols) == sorted(want.cols)
    for c in got.cols:
        np.testing.assert_array_equal(
            np.asarray(_host(got.cols[c])), np.asarray(_host(want.cols[c])),
            err_msg=f"column {c}")


# ---------------------------------------------------------------------------
# staged AOT handles
# ---------------------------------------------------------------------------


def test_compiled_query_join_matches_oracle():
    probe, build = _tables()
    cq = Query(Table(dict(probe))).compile().join(
        Table(dict(build)), on="k", validate="m:1")
    _assert_tables_equal(cq.run(), _oracle_join(probe, build, on="k",
                                                validate="m:1"))
    assert cq.stats["cache.misses"] >= 1
    assert "compile_ms" in cq.stats


def test_compiled_query_agg_and_group_agg():
    probe, _ = _tables(n=5000, k=8)
    t = Table(dict(probe))
    cq = Query(t).compile().agg({"s": (t.col("x"), "+"),
                                 "m": (t.col("x"), "max")})
    out = cq.run()
    assert out["s"] == pytest.approx(probe["x"].sum())
    assert out["m"] == pytest.approx(probe["x"].max())

    t2 = Table(dict(probe))
    cg = Query(t2).compile().group_agg(
        [t2.col("k")], {"s": (t2.col("x"), "+")})
    got = cg.run()
    te = Table(dict(probe), eager=True)
    want = Query(te).group_agg([te.col("k")], {"s": (te.col("x"), "+")})
    assert set(got) == set(want)
    for key in want:
        assert got[key][0] == pytest.approx(want[key][0])
        assert got[key][1] == want[key][1]


def test_compiled_query_rebind_zero_recompiles():
    probe, build = _tables()
    cq = Query(Table(dict(probe))).compile().join(
        Table(dict(build)), on="k", validate="m:1")
    cq.run()
    misses = runtime.cache_stats()["cache.misses"]

    probe2, build2 = _tables(seed=7)
    out = cq.run(table=Table(dict(probe2)), right=Table(dict(build2)))
    assert runtime.cache_stats()["cache.misses"] == misses, \
        "re-binding same-shape inputs must not recompile"
    _assert_tables_equal(out, _oracle_join(probe2, build2, on="k",
                                           validate="m:1"))


def test_compiled_query_rebind_shape_mismatch_raises():
    probe, build = _tables()
    cq = Query(Table(dict(probe))).compile().join(
        Table(dict(build)), on="k", validate="m:1")
    smaller, _ = _tables(n=123)
    with pytest.raises(ValueError, match="signature"):
        cq.run(table=Table(dict(smaller)))
    with pytest.raises(KeyError, match="alias"):
        cq.run(nonsense=Table(dict(probe)))


def test_stage_requires_lazy_table():
    probe, build = _tables(n=100, k=10)
    with pytest.raises(ValueError, match="lazy"):
        Query(Table(dict(probe), eager=True)).stage().join(
            Table(dict(build), eager=True), on="k")


def test_explain_carries_cost_source():
    probe, build = _tables()
    cq = Query(Table(dict(probe))).compile().join(
        Table(dict(build)), on="k", validate="m:1")
    rendered = cq.explain().render()
    assert "source=roofline" in rendered


# ---------------------------------------------------------------------------
# concurrent serving
# ---------------------------------------------------------------------------


def test_concurrent_mixed_queries_byte_identical_single_flight():
    """N worker threads x mixed same-shape/different-shape joins and
    group-bys: byte-identical to the serial oracle, exactly ONE compile
    per distinct (plan, shape) key."""
    pa, ba = _tables(n=20000, k=100, seed=1)
    pb, bb = _tables(n=7000, k=50, seed=2)

    def staged_join_a():
        return Query(Table(dict(pa))).stage().join(
            Table(dict(ba)), on="k", validate="m:1")

    def staged_join_b():  # different shape -> distinct key
        return Query(Table(dict(pb))).stage().join(
            Table(dict(bb)), on="k", validate="m:1")

    def staged_join_mn():  # m:n build side (duplicate keys)
        dup = {"k": np.concatenate([ba["k"], ba["k"]]),
               "w": np.concatenate([ba["w"], ba["w"] + 1.0])}
        return Query(Table(dict(pa))).stage().join(
            Table(dict(dup)), on="k")

    def staged_group():
        t = Table(dict(pa))
        return Query(t).stage().group_agg(
            [t.col("k")], {"s": (t.col("x"), "+")})

    makers = [staged_join_a, staged_join_b, staged_join_mn, staged_group]
    # serial oracles (eager paths / fresh-cache lazy for group_agg)
    dup = {"k": np.concatenate([ba["k"], ba["k"]]),
           "w": np.concatenate([ba["w"], ba["w"] + 1.0])}
    te = Table(dict(pa), eager=True)
    oracles = [
        _oracle_join(pa, ba, on="k", validate="m:1"),
        _oracle_join(pb, bb, on="k", validate="m:1"),
        _oracle_join(pa, dup, on="k"),
        Query(te).group_agg([te.col("k")], {"s": (te.col("x"), "+")}),
    ]

    runtime.clear_cache()
    reqs = [makers[i % len(makers)]() for i in range(24)]
    with QueryServer(workers=6) as srv:
        results = [f.result() for f in [srv.submit(q) for q in reqs]]
    st = srv.stats()

    distinct = len(makers)
    assert st["cache.misses"] == distinct, st
    assert st["cache.hits"] + st["cache.waits"] == len(reqs) - distinct, st
    assert runtime.cache_size() == distinct
    assert st["serve.completed"] == len(reqs)
    assert st["serve.shed"] == 0

    for i, got in enumerate(results):
        want = oracles[i % len(makers)]
        if isinstance(got, Table):
            _assert_tables_equal(got, want)
        else:
            assert set(got) == set(want)
            for key in want:
                np.testing.assert_allclose(
                    np.asarray(got[key], dtype=float),
                    np.asarray(want[key], dtype=float))


def test_single_flight_one_compile_under_thundering_herd():
    probe, build = _tables()
    reqs = [Query(Table(dict(probe))).stage().join(
        Table(dict(build)), on="k", validate="m:1") for _ in range(16)]
    runtime.clear_cache()
    start = threading.Barrier(8)

    outs = []
    errs = []
    lock = threading.Lock()

    with QueryServer(workers=8) as srv:
        def fire(q):
            start.wait()
            try:
                r = srv.run(q)
                with lock:
                    outs.append(r)
            except BaseException as e:  # pragma: no cover
                with lock:
                    errs.append(e)

        threads = [threading.Thread(target=fire, args=(q,)) for q in reqs]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert not errs
    st = runtime.cache_stats()
    assert st["cache.misses"] == 1, st
    assert st["cache.hits"] + st["cache.waits"] == len(reqs) - 1, st
    oracle = _oracle_join(probe, build, on="k", validate="m:1")
    for o in outs:
        _assert_tables_equal(o, oracle)


def test_cache_eviction_bounded(monkeypatch):
    monkeypatch.setenv(runtime.ENV_CACHE_MAX, "2")
    runtime.clear_cache()
    for i in range(5):  # distinct probe shapes -> distinct cache keys
        probe, build = _tables(n=1000 + 100 * i, k=20)
        Query(Table(dict(probe))).join(
            Table(dict(build)), on="k", validate="m:1")
    st = runtime.cache_stats()
    assert runtime.cache_size() <= 2
    assert st["cache.evictions"] >= 3
    assert st["cache.misses"] == 5


def test_serve_sheds_with_typed_resource_error():
    probe, build = _tables()
    staged = Query(Table(dict(probe))).stage().join(
        Table(dict(build)), on="k", validate="m:1")
    runtime.clear_cache()
    with QueryServer(workers=2, memory_limit=64) as srv:
        fut = srv.submit(staged)
        with pytest.raises(ResourceError, match="at admission"):
            fut.result()
        st = srv.stats()
    assert st["serve.shed"] == 1
    assert st["serve.errors"] == 0, "a shed is not an error"
    assert runtime.cache_size() == 0, \
        "a shed plan must never enter the compile cache"


def test_serve_accepts_weldobject():
    a = weldnp.array(np.arange(1000, dtype=np.float64))
    b = (a * 2.0) + 1.0
    with QueryServer(workers=2) as srv:
        out = srv.run(b.obj)
    np.testing.assert_allclose(
        np.asarray(out), np.arange(1000, dtype=np.float64) * 2.0 + 1.0)


# ---------------------------------------------------------------------------
# satellite bugfix: stale-key refile leak
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_first_encounter_tuning_files_one_entry():
    """First-encounter autotuning refreshes the fingerprint mid-compile;
    the executable must be re-filed under the refreshed key ONLY — the
    pre-tuning key can never match again and caching it leaked one dead
    entry per tuned plan."""
    rng = np.random.default_rng(0)
    x = weldnp.array(rng.normal(size=4096))
    runtime.clear_cache()
    st = {}
    Evaluate((x * 2.0).sum().obj, kernelize="always",
             kernel_impl="interpret", collect_stats=st)
    assert st.get("kernelplan", {}).get("autotune"), \
        "expected a first-encounter tuning event"
    assert runtime.cache_size() == 1, \
        "refile must evict the stale pre-tuning key (leak: size grew to 2)"
    misses = runtime.cache_stats()["cache.misses"]
    res = Evaluate((x * 2.0).sum().obj, kernelize="always",
                   kernel_impl="interpret")
    assert res.from_cache
    assert runtime.cache_stats()["cache.misses"] == misses
    assert runtime.cache_size() == 1


# ---------------------------------------------------------------------------
# satellite bugfix: admission must degrade, not die
# ---------------------------------------------------------------------------


def test_admission_certificate_failure_degrades(monkeypatch):
    """The bounds contract says analysis failures only disable
    admission — that must cover certificate *evaluation* (peak/
    certificate/builder_lines), not just analyze()."""
    def boom(self, shapes=None):
        raise RuntimeError("injected: certificate evaluation fault")

    monkeypatch.setattr(_bounds.BoundsReport, "peak", boom)
    probe, build = _tables(n=2000, k=20)
    st = {}
    out = Query(Table(dict(probe))).join(
        Table(dict(build)), on="k", validate="m:1",
        memory_limit=1 << 31, collect_stats=st)
    _assert_tables_equal(out, _oracle_join(probe, build, on="k",
                                           validate="m:1"))
    assert "injected" in st.get("bounds.degraded", "")
    assert "bounds.certificate" not in st
    assert "bounds.admitted" not in st


# ---------------------------------------------------------------------------
# satellite bugfix: ledger torn writes + bare-filename path
# ---------------------------------------------------------------------------


def test_ledger_read_skips_torn_tail_with_warning(tmp_path):
    p = tmp_path / "torn.jsonl"
    good = {"kernel": "hash_probe", "dtype": "float64", "n": 4096,
            "bucket": 4096, "predicted_ns": 1000, "measured_ns": 1200}
    with open(p, "w") as f:
        f.write(json.dumps(good) + "\n")
        f.write(json.dumps(good) + "\n")
        f.write(json.dumps(good)[:17])  # killed mid-append
    with pytest.warns(RuntimeWarning, match=r"torn\.jsonl.*line 3"):
        recs = ledger.read(str(p))
    assert len(recs) == 2


def test_ledger_path_bare_autotune_filename_is_absolute(monkeypatch):
    monkeypatch.delenv("WELD_COST_LEDGER", raising=False)
    monkeypatch.setenv("WELD_AUTOTUNE_CACHE", "autotune.json")
    p = ledger.ledger_path()
    assert os.path.isabs(p)
    assert os.path.dirname(p) == os.getcwd()


# ---------------------------------------------------------------------------
# calibration overlay
# ---------------------------------------------------------------------------


def _seed_ledger(kernel, dtype, n, measured_ns, count=3):
    for _ in range(count):
        ledger.record(kernel, dtype, n, None, measured_ns)
    calibrate.invalidate()


def test_calibrate_overlay_switches_source_and_routing():
    from repro.core.kernelplan import cost
    from repro.core.kernelplan import registry as reg

    spec = reg.get("filter_reduce_sum")
    meta = {"kernel": "filter_reduce_sum", "n": 200000, "cols": 1,
            "n_aggs": 1, "ops": 1, "dtype": "float64"}
    base = cost.estimate(spec, meta)
    assert base.source == "roofline"
    assert "source=roofline" in base.why

    # a huge measured median must flip the gate to reject
    _seed_ledger("filter_reduce_sum", "float64", 200000, int(5e9))
    est = cost.estimate(spec, meta)
    assert est.source == "measured"
    assert "source=measured" in est.why
    assert not est.routed
    assert est.kernel_s == pytest.approx(5.0)

    # a tiny one must route
    os.remove(ledger.ledger_path())
    calibrate.invalidate()
    _seed_ledger("filter_reduce_sum", "float64", 200000, 10)
    est = cost.estimate(spec, meta)
    assert est.source == "measured" and est.routed


def test_calibrate_needs_min_samples_and_honors_disable(monkeypatch):
    from repro.core.kernelplan import cost
    from repro.core.kernelplan import registry as reg

    spec = reg.get("filter_reduce_sum")
    meta = {"kernel": "filter_reduce_sum", "n": 200000, "cols": 1,
            "n_aggs": 1, "ops": 1, "dtype": "float64"}
    _seed_ledger("filter_reduce_sum", "float64", 200000, int(5e9), count=2)
    est = cost.estimate(spec, meta)
    assert est.source == "roofline", "2 samples < min_samples must stay roofline"

    _seed_ledger("filter_reduce_sum", "float64", 200000, int(5e9), count=1)
    assert cost.estimate(spec, meta).source == "measured"

    monkeypatch.setenv("WELD_CALIBRATE", "0")
    assert cost.estimate(spec, meta).source == "roofline"


def test_quarantined_entries_keep_exact_why(monkeypatch):
    """Calibration must not touch the quarantine path: its why string is
    load-bearing (exact-match asserted by the recovery tests)."""
    monkeypatch.setattr(
        quarantine, "is_quarantined",
        lambda kernel, impl=None, dtype=None, n=None: True)
    # seed medians so the overlay WOULD fire if it saw these candidates
    _seed_ledger("hash_probe", "int64", 2000, 10)
    probe, build = _tables(n=2000, k=20)
    st = {}
    runtime.clear_cache()
    Query(Table(dict(probe))).join(Table(dict(build)), on="k",
                                   validate="m:1", collect_stats=st)
    costs = st.get("kernelplan", {}).get("costs", [])
    qrows = [c for c in costs if c.get("why") == "quarantined"]
    assert qrows and all(not c["routed"] for c in qrows)
