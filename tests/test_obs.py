"""weldtrace observability tests: the span tracer, Chrome-trace export,
EXPLAIN [ANALYZE], and the predicted-vs-measured cost ledger."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import obs
from repro.core.obs import ledger


@pytest.fixture(autouse=True)
def clean_tracer(tmp_path, monkeypatch):
    """Every test starts with tracing off, an empty span log, and a
    private ledger/autotune location."""
    monkeypatch.setenv("WELD_COST_LEDGER",
                       str(tmp_path / "cost_ledger.jsonl"))
    monkeypatch.setenv("WELD_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_disabled_tracer_is_noop():
    assert not obs.enabled()
    sp = obs.span("anything", tag=1)
    assert sp is obs.NOOP
    sp.set("x", 2).count("y")
    with obs.span("nested"):
        pass
    obs.event("evt")
    assert obs.spans() == []


def test_spans_nest_and_time():
    obs.enable()
    with obs.span("outer", who="t") as outer:
        with obs.span("inner") as inner:
            inner.count("items", 3)
        with obs.span("inner2"):
            pass
    spans = obs.spans()
    assert [s.name for s in spans] == ["outer", "inner", "inner2"]
    assert outer.depth == 0 and inner.depth == 1
    assert outer.dur_ns >= inner.dur_ns >= 0
    # children sit inside the parent interval
    assert inner.start_ns >= outer.start_ns
    assert inner.start_ns + inner.dur_ns <= outer.start_ns + outer.dur_ns
    assert inner.counters == {"items": 3}
    assert outer.tags == {"who": "t"}


def test_mark_and_spans_since():
    obs.enable()
    with obs.span("before"):
        pass
    pos = obs.mark()
    with obs.span("after"):
        pass
    assert [s.name for s in obs.spans_since(pos)] == ["after"]


def test_event_is_instant_and_keeps_nesting():
    obs.enable()
    with obs.span("parent"):
        obs.event("tick", n=1)
        with obs.span("child"):
            pass
    spans = {s.name: s for s in obs.spans()}
    assert spans["tick"].dur_ns == 0
    assert spans["tick"].depth == 1
    assert spans["child"].depth == 1  # event didn't leak onto the stack


def test_env_enable(monkeypatch):
    from repro.core.obs import tracer

    monkeypatch.setenv(tracer.ENV_TRACE, "1")
    assert tracer._env_enabled()
    monkeypatch.setenv(tracer.ENV_TRACE, "0")
    assert not tracer._env_enabled()
    monkeypatch.setenv(tracer.ENV_TRACE, "false")
    assert not tracer._env_enabled()
    monkeypatch.delenv(tracer.ENV_TRACE)
    assert not tracer._env_enabled()


def test_chrome_export_valid_and_monotonic(tmp_path):
    obs.enable()
    with obs.span("a", kind="outer"):
        with obs.span("b"):
            obs.event("e")
    path = obs.dump_chrome(str(tmp_path / "trace.json"))
    data = json.loads(open(path).read())
    evs = data["traceEvents"]
    assert [e["name"] for e in evs] == ["a", "b", "e"]
    for e in evs:
        assert e["ph"] == "X"
        assert e["ts"] >= 0 and e["dur"] >= 0
    a, b = evs[0], evs[1]
    assert b["ts"] >= a["ts"]
    assert b["ts"] + b["dur"] <= a["ts"] + a["dur"]
    assert evs[0]["args"]["kind"] == "outer"


def test_format_tree_renders_nesting():
    obs.enable()
    with obs.span("root", q=1):
        with obs.span("leaf"):
            pass
    txt = obs.format_tree()
    lines = txt.splitlines()
    assert lines[0].startswith("root") and "q=1" in lines[0]
    assert lines[1].startswith("  leaf")


def test_unserializable_tag_survives_chrome_export():
    obs.enable()
    with obs.span("s", obj=object()):
        pass
    data = obs.to_chrome()
    json.dumps(data)  # must not raise
    assert "object" in data["traceEvents"][0]["args"]["obj"]


# ---------------------------------------------------------------------------
# pipeline integration: spans appear through runtime/passes/planner
# ---------------------------------------------------------------------------


def _join_tables(n=4096, k=64, fanout=4):
    from repro.frames import weldrel

    rng = np.random.RandomState(7)
    rkey = np.repeat(np.arange(k, dtype=np.int64), fanout)
    right = weldrel.Table({"key": rkey, "rate": rng.rand(rkey.size)})
    left = weldrel.Table({
        "key": rng.randint(0, 2 * k, n).astype(np.int64),
        "price": rng.rand(n),
    })
    return weldrel, left, right


def test_evaluate_emits_pipeline_spans():
    from repro.core import runtime
    from repro.frames import weldnp

    runtime.clear_cache()
    obs.enable()
    x = weldnp.array(np.arange(1000, dtype=np.float64))
    ((x + 1.0) * 2.0).evaluate()
    names = [s.name for s in obs.spans()]
    for want in ("weld.evaluate", "encode", "cache.lookup", "optimize",
                 "pass.fusion", "jit_compile", "execute", "decode"):
        assert want in names, (want, names)
    # second run: cache hit — compile-side spans absent, execute present
    pos = obs.mark()
    ((x + 1.0) * 2.0).evaluate()
    names2 = [s.name for s in obs.spans_since(pos)]
    assert "execute" in names2 and "optimize" not in names2
    hit = [s for s in obs.spans_since(pos) if s.name == "cache.lookup"]
    assert hit and hit[0].tags["hit"] is True


# ---------------------------------------------------------------------------
# EXPLAIN [ANALYZE]
# ---------------------------------------------------------------------------


def test_explain_reports_plan_without_tracing():
    weldrel, left, right = _join_tables()
    rep = weldrel.Query(left).explain().join(right, on="key",
                                             kernelize="always")
    assert not obs.enabled()  # explain() alone must not flip tracing on
    txt = rep.render()
    assert "EXPLAIN weldrel.join" in txt
    assert "kernel[group_build]" in txt
    assert "routed kernels" in txt
    assert rep.spans == []
    kernels = {r["kernel"] for r in rep.kernels()}
    assert {"group_build", "group_probe"} <= kernels
    # the report still carries the operator's result
    assert "price" in rep.result.cols


def test_explain_analyze_mn_join_measures_group_kernels():
    """Acceptance: explain(analyze=True) on a kernelized m:n join shows
    group_build AND group_probe launches with predicted + measured."""
    weldrel, left, right = _join_tables()
    rep = weldrel.Query(left).explain(analyze=True).join(
        right, on="key", kernelize="always")
    assert not obs.enabled()  # restored afterwards
    rows = {r["kernel"]: r for r in rep.kernel_spans()}
    for kern in ("group_build", "group_probe"):
        assert kern in rows, rows
        assert rows[kern]["predicted_ns"], rows[kern]
        assert rows[kern]["measured_ns"], rows[kern]
        assert rows[kern]["ratio"] > 0
    txt = rep.render()
    assert "EXPLAIN ANALYZE" in txt
    assert "predicted vs measured" in txt
    assert "span tree" in txt


def test_explain_rejects_eager_tables():
    from repro.frames import weldrel

    t = weldrel.Table({"a": np.arange(4)}, eager=True)
    with pytest.raises(ValueError, match="lazy"):
        weldrel.Query(t).explain().agg({"s": (t.col("a"), "+")})


def test_group_agg_accepts_collect_stats():
    weldrel, left, _ = _join_tables()
    st: dict = {}
    out = weldrel.Query(left).group_agg(
        [left.col("key")], {"s": (left.col("price"), "+")},
        capacity=256, kernelize="auto", collect_stats=st)
    assert out and "loops.before" in st


# ---------------------------------------------------------------------------
# cost ledger + report CLI
# ---------------------------------------------------------------------------


def test_ledger_roundtrip_and_summary(tmp_path):
    path = str(tmp_path / "l.jsonl")
    for i in range(3):
        rec = ledger.record("k1", "float64", 5000, predicted_ns=1000,
                            measured_ns=2000 + i, path=path)
        assert rec["bucket"] == 8192
    ledger.record("k2", "int64", 100, predicted_ns=None,
                  measured_ns=500, path=path)
    with open(path, "a") as f:
        f.write("{corrupt json\n")  # truncated tail must be skipped
    recs = ledger.read(path)
    assert len(recs) == 4
    rows = ledger.summarize(recs)
    by_kernel = {r["kernel"]: r for r in rows}
    assert by_kernel["k1"]["calls"] == 3
    assert by_kernel["k1"]["ratio"] == pytest.approx(2.0, abs=0.01)
    assert by_kernel["k1"]["log2_err"] == pytest.approx(1.0, abs=0.01)
    assert by_kernel["k2"]["ratio"] is None  # no prediction recorded
    txt = ledger.format_report(rows)
    assert "k1" in txt and "k2" in txt


def test_traced_execution_appends_ledger(tmp_path):
    weldrel, left, right = _join_tables()
    path = os.environ["WELD_COST_LEDGER"]
    weldrel.Query(left).explain(analyze=True).join(right, on="key",
                                                   kernelize="always")
    recs = ledger.read(path)
    kernels = {r["kernel"] for r in recs}
    assert {"group_build", "group_probe"} <= kernels
    for r in recs:
        assert r["measured_ns"] > 0
        assert r["bucket"] >= 1024


def test_cost_report_cli(tmp_path):
    path = str(tmp_path / "l.jsonl")
    ledger.record("group_probe", "float64", 4096, predicted_ns=1500,
                  measured_ns=4500, path=path)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, os.path.join(repo, "tools", "cost_report.py"),
         "--ledger", path, "--json"],
        capture_output=True, text=True, check=True,
    )
    data = json.loads(out.stdout)
    assert data["records"] == 1
    assert data["groups"][0]["kernel"] == "group_probe"
    assert data["groups"][0]["ratio"] == pytest.approx(3.0, abs=0.01)


def test_repro_obs_alias():
    import repro.obs as topobs

    assert topobs.enable is obs.enable
    assert topobs.ledger is ledger
