"""Distribution tests.  Multi-device cases run in a subprocess with 8
fake host devices (XLA_FLAGS must be set before jax init, and the main
test process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # subprocess meshes: minutes, not seconds

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    prog = textwrap.dedent(code)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    payload = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert payload, out.stdout
    return json.loads(payload[-1][len("RESULT "):])


# ---------------------------------------------------------------------------
# sharding rules (single process — pure spec math needs a mesh though)
# ---------------------------------------------------------------------------


def test_spec_rules_divisibility_fallback():
    res = run_sub("""
        import jax, json
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import spec_for_leaf
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # kv_heads=2 not divisible by model=4 -> falls back to head_dim
        s1 = spec_for_leaf((64, 2, 16), ("embed", "kv_heads", "head_dim"), mesh)
        # heads divisible -> model; head_dim must stay unsharded (axis used)
        s2 = spec_for_leaf((64, 8, 16), ("embed", "heads", "head_dim"), mesh)
        # experts take model; mlp falls back to nothing
        s3 = spec_for_leaf((8, 64, 32), ("experts", "embed", "mlp"), mesh)
        # batch -> data
        s4 = spec_for_leaf((8, 128), ("batch", None), mesh)
        print("RESULT " + json.dumps({
            "s1": list(s1), "s2": list(s2), "s3": list(s3), "s4": list(s4),
        }))
    """)
    assert res["s1"] == [None, None, "model"]
    assert res["s2"] == [None, "model", None]
    assert res["s3"] == ["model", None, None]
    assert res["s4"] == ["data", None]


def test_multi_axis_batch_rule():
    res = run_sub("""
        import jax, json
        from repro.distributed.sharding import spec_for_leaf
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        s = spec_for_leaf((8, 32), ("batch", None), mesh)
        print("RESULT " + json.dumps({"s": [list(x) if isinstance(x, tuple)
                                            else x for x in s]}))
    """)
    assert res["s"] == [["pod", "data"], None]


# ---------------------------------------------------------------------------
# sharded training equivalence
# ---------------------------------------------------------------------------


def test_sharded_training_matches_single_device():
    """3 steps on mesh (4 data x 2 model) == 3 steps on (1 x 1)."""
    code = """
        import jax, json
        import numpy as np
        from repro.launch.train import train
        o_single = train("llama3.2-3b", smoke=True, steps=3, global_batch=4,
                         seq_len=16, dp=1, tp=1, verbose=False)
        o_shard = train("llama3.2-3b", smoke=True, steps=3, global_batch=4,
                        seq_len=16, dp=4, tp=2, verbose=False)
        print("RESULT " + json.dumps({
            "single": o_single["losses"], "shard": o_shard["losses"]}))
    """
    res = run_sub(code)
    np.testing.assert_allclose(res["single"], res["shard"], rtol=2e-4,
                               atol=2e-5)


def test_moe_expert_parallel_training():
    code = """
        import json
        from repro.launch.train import train
        o = train("deepseek-moe-16b", smoke=True, steps=3, global_batch=4,
                  seq_len=16, dp=2, tp=4, verbose=False)
        import numpy as np
        ok = all(np.isfinite(o["losses"]))
        print("RESULT " + json.dumps({"ok": bool(ok), "losses": o["losses"]}))
    """
    res = run_sub(code)
    assert res["ok"]


def test_elastic_restart_across_meshes(tmp_path):
    """Checkpoint on (4,2), resume on (2,1): loss trajectory continues as
    if uninterrupted (pipeline shard-stability + unsharded checkpoints)."""
    d = str(tmp_path / "ck")
    code = f"""
        import jax, json
        from repro.launch.train import train
        # phase 1 on 4x2
        train("llama3.2-3b", smoke=True, steps=4, global_batch=4, seq_len=16,
              dp=4, tp=2, ckpt_dir={d!r}, ckpt_every=4, verbose=False)
        # phase 2 resumes on 2x1 (elastic shrink)
        o2 = train("llama3.2-3b", smoke=True, steps=8, global_batch=4,
                   seq_len=16, dp=2, tp=1, ckpt_dir={d!r}, resume=True,
                   ckpt_every=100, verbose=False)
        # uninterrupted reference on 1x1
        o_ref = train("llama3.2-3b", smoke=True, steps=8, global_batch=4,
                      seq_len=16, dp=1, tp=1, verbose=False)
        print("RESULT " + json.dumps({{
            "resumed_tail": o2["losses"][-4:],
            "ref_tail": o_ref["losses"][-4:]}}))
    """
    res = run_sub(code)
    np.testing.assert_allclose(res["resumed_tail"], res["ref_tail"],
                               rtol=5e-4, atol=5e-5)


def test_remesh_preserves_values():
    code = """
        import jax, json
        import jax.numpy as jnp
        import numpy as np
        from repro.distributed.elastic import remesh
        from repro.distributed.sharding import tree_shardings
        m1 = jax.make_mesh((4, 2), ("data", "model"))
        m2 = jax.make_mesh((2, 4), ("data", "model"))
        spec = {"w": ("batch", "mlp")}
        x = {"w": jnp.arange(8 * 8, dtype=jnp.float32).reshape(8, 8)}
        sh1 = tree_shardings(spec, jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), x), m1)
        xs = jax.device_put(x, sh1)
        xr = remesh(xs, spec, m2)
        same = bool(np.array_equal(np.asarray(xr["w"]), np.asarray(x["w"])))
        nshards = len(xr["w"].sharding.device_set)
        print("RESULT " + json.dumps({"same": same, "nshards": nshards}))
    """
    res = run_sub(code)
    assert res["same"] and res["nshards"] == 8


def test_compressed_psum_error_feedback():
    """int8 EF all-reduce: single-step error bounded; telescoped error
    over steps stays bounded (error feedback works)."""
    code = """
        import jax, json
        import jax.numpy as jnp
        import numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum, init_error_buffers
        mesh = jax.make_mesh((8,), ("pod",))
        g = jnp.asarray(np.random.RandomState(0).randn(8, 256),
                        jnp.float32)

        @partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
                 out_specs=(P("pod"), P("pod")))
        def sync(gl, el):
            m, ne = compressed_psum(gl[0], el[0], "pod")
            return m[None], ne[None]

        err = jnp.zeros_like(g)
        exact = jnp.mean(g, axis=0)
        errs = []
        for step in range(5):
            synced, err = sync(g, err)
            rel = float(jnp.linalg.norm(synced[0] - exact)
                        / jnp.linalg.norm(exact))
            errs.append(rel)
        print("RESULT " + json.dumps({"rels": errs}))
    """
    res = run_sub(code)
    # int8 quantization: each step's sync error small; EF keeps it bounded
    assert all(r < 0.05 for r in res["rels"]), res["rels"]


def test_dryrun_small_mesh_all_archs_smoke():
    """A miniature dry-run: lower+compile train & decode for every arch's
    SMOKE config on a 2x4 mesh — proves the sharding rules are coherent
    for every family without the full-size cost."""
    code = """
        import jax, json
        from repro.launch.dryrun import dryrun_cell
        from repro.configs import list_configs
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = {}
        for arch in list_configs():
            if arch == "weld-bench":
                continue
            r = dryrun_cell(arch, "train_4k", mesh, smoke=True,
                            batch_override=4, seq_override=32)
            out[arch + "/train"] = r["ok"]
            r2 = dryrun_cell(arch, "decode_32k", mesh, smoke=True,
                             batch_override=4, seq_override=32)
            out[arch + "/decode"] = r2["ok"]
        print("RESULT " + json.dumps(out))
    """
    res = run_sub(code)
    bad = [k for k, v in res.items() if not v]
    assert not bad, f"dry-run failed for {bad}"
