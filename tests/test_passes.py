"""Optimizer pass tests: every pass preserves semantics (checked against
the pure-Python reference interpreter) and performs its structural job."""
import numpy as np
import pytest

from repro.core import ir, macros as M, wtypes as wt
from repro.core.interp import interpret
from repro.core.passes import loop_count, optimize
from repro.core.passes.predication import predicate
from repro.core.passes.size import size_analysis


def _vec_ident(name="v"):
    return ir.Ident(name, wt.Vec(wt.I64))


def _check_equiv(expr, env):
    """optimizer output must agree with the unoptimized interpreter."""
    before = interpret(expr, env)
    after = interpret(optimize(expr), env)
    assert before == after
    return optimize(expr)


def test_vertical_fusion_map_map():
    v = _vec_ident()
    inner = M.map_(v, lambda x: ir.BinOp("+", x, M.lit(1)))
    outer = M.map_(inner, lambda x: ir.BinOp("*", x, M.lit(2)))
    assert loop_count(outer) == 2
    opt = _check_equiv(outer, {"v": [1, 2, 3]})
    assert loop_count(opt) == 1


def test_vertical_fusion_filter_reduce():
    """Listing 9 -> Listing 10."""
    v = _vec_ident()
    f = M.filter_(v, lambda x: ir.BinOp(">", x, M.lit(500000)))
    s = M.reduce_(f, "+")
    opt = _check_equiv(s, {"v": [1, 600000, 700000, 3]})
    assert loop_count(opt) == 1
    assert interpret(opt, {"v": [1, 600000, 700000, 3]}) == 1300000


def test_vertical_fusion_preserves_index_maps():
    """Consumer uses its index: only legal over map-like producers."""
    v = _vec_ident()
    inner = M.map_(v, lambda x: ir.BinOp("*", x, M.lit(3)))
    # consumer multiplies by index
    et = wt.I64
    bt = wt.VecBuilder(et)
    b, i, x = (ir.Ident(ir.fresh(n), t) for n, t in
               (("b", bt), ("i", wt.I64), ("x", et)))
    outer = ir.Result(ir.For(
        (ir.Iter(inner),), ir.NewBuilder(bt),
        ir.Lambda((b, i, x), ir.Merge(b, ir.BinOp("*", x, i))),
    ))
    opt = _check_equiv(outer, {"v": [5, 6, 7]})
    assert loop_count(opt) == 1  # map-like producer: fusion legal


def test_no_fusion_filter_then_indexed_consumer():
    """Filter producer + index-using consumer must NOT fuse."""
    v = _vec_ident()
    f = M.filter_(v, lambda x: ir.BinOp(">", x, M.lit(2)))
    bt = wt.VecBuilder(wt.I64)
    b, i, x = (ir.Ident(ir.fresh(n), t) for n, t in
               (("b", bt), ("i", wt.I64), ("x", wt.I64)))
    outer = ir.Result(ir.For(
        (ir.Iter(f),), ir.NewBuilder(bt),
        ir.Lambda((b, i, x), ir.Merge(b, ir.BinOp("+", x, i))),
    ))
    env = {"v": [1, 5, 2, 7]}
    opt = optimize(outer)
    assert interpret(opt, env) == interpret(outer, env) == [5, 8]
    assert loop_count(opt) == 2  # fusion correctly refused


def test_horizontal_fusion_listing2_to_3():
    v = _vec_ident()
    prog = ir.Let(
        "r1", M.map_(v, lambda x: ir.BinOp("+", x, M.lit(1))),
        ir.Let(
            "r2", M.reduce_(v, "+"),
            ir.MakeStruct((ir.Ident("r1", wt.Vec(wt.I64)),
                           ir.Ident("r2", wt.I64))),
        ),
    )
    opt = _check_equiv(prog, {"v": [1, 2, 3]})
    assert loop_count(opt) == 1
    assert interpret(opt, {"v": [1, 2, 3]}) == ([2, 3, 4], 6)


def test_horizontal_fusion_three_loops():
    v = _vec_ident()
    prog = ir.Let(
        "a", M.reduce_(v, "+"),
        ir.Let(
            "b", M.reduce_(v, "max"),
            ir.Let(
                "c", M.map_(v, lambda x: ir.BinOp("*", x, M.lit(2))),
                ir.MakeStruct((
                    ir.Ident("a", wt.I64), ir.Ident("b", wt.I64),
                    ir.Ident("c", wt.Vec(wt.I64)),
                )),
            ),
        ),
    )
    opt = _check_equiv(prog, {"v": [4, 1, 7]})
    assert loop_count(opt) == 1


def test_horizontal_fusion_respects_dependencies():
    """Second loop consumes the first's result: vertical (not horizontal)
    fusion applies and the chain still collapses to one loop."""
    v = _vec_ident()
    prog = ir.Let(
        "a", M.map_(v, lambda x: ir.BinOp("+", x, M.lit(1))),
        ir.Let(
            "b", M.reduce_(ir.Ident("a", wt.Vec(wt.I64)), "+"),
            ir.Ident("b", wt.I64),
        ),
    )
    opt = _check_equiv(prog, {"v": [1, 2, 3]})
    assert loop_count(opt) == 1
    assert interpret(opt, {"v": [1, 2, 3]}) == 9


def test_predication_rewrites_if_merge():
    v = _vec_ident()
    e = M.filter_reduce(v, lambda x: ir.BinOp(">", x, M.lit(0)), "+")
    stats = {}
    out = predicate(e, stats)
    assert stats.get("predication") == 1
    assert interpret(out, {"v": [-1, 2, -3, 4]}) == \
        interpret(e, {"v": [-1, 2, -3, 4]}) == 6
    # the If is gone from the loop body
    assert not any(isinstance(n, ir.If) for n in ir.walk(out))


def test_predication_min_identity():
    v = _vec_ident()
    e = M.filter_reduce(v, lambda x: ir.BinOp(">", x, M.lit(0)), "min")
    out = predicate(e, {})
    env = {"v": [5, -2, 3]}
    assert interpret(out, env) == interpret(e, env) == 3


def test_predication_skips_dictmerger():
    keys = ir.Ident("k", wt.Vec(wt.I64))
    vals = ir.Ident("w", wt.Vec(wt.I64))
    bt = wt.DictMerger(wt.I64, wt.I64, "+")
    b, i, x = (ir.Ident(ir.fresh(n), t) for n, t in
               (("b", bt), ("i", wt.I64), ("x", wt.Struct((wt.I64, wt.I64)))))
    e = ir.Result(ir.For(
        (ir.Iter(keys), ir.Iter(vals)),
        ir.NewBuilder(bt, arg=ir.Literal(16, wt.I64)),
        ir.Lambda((b, i, x), ir.If(
            ir.BinOp(">", ir.GetField(x, 1), M.lit(0)), ir.Merge(b, x), b)),
    ))
    stats = {}
    out = predicate(e, stats)
    assert "predication" not in stats  # sentinel keys would corrupt a dict


def test_size_analysis_annotates_map():
    v = _vec_ident()
    e = M.map_(v, lambda x: x)
    stats = {}
    out = size_analysis(e, stats)
    assert stats.get("size.hints") == 1
    nb = [n for n in ir.walk(out) if isinstance(n, ir.NewBuilder)][0]
    assert nb.size_hint is not None


def test_size_analysis_skips_filter():
    v = _vec_ident()
    e = M.filter_(v, lambda x: ir.BinOp(">", x, M.lit(0)))
    stats = {}
    size_analysis(e, stats)
    assert "size.hints" not in stats


def test_tiling_raises_dot_and_matvec():
    mat = ir.Ident("m", wt.Vec(wt.Vec(wt.F64)))
    w = ir.Ident("w", wt.Vec(wt.F64))
    e = M.map_(mat, lambda row: M.dot(row, w), out_ty=wt.F64)
    stats = {}
    opt = optimize(e, stats=stats)
    assert stats.get("tiling.matvec", 0) >= 1
    assert any(isinstance(n, ir.CUDF) and n.name == "linalg.matvec"
               for n in ir.walk(opt))


def test_cse_dedupes_identical_chains():
    v = _vec_ident()
    mk = lambda: M.map_(v, lambda x: ir.BinOp("*", x, M.lit(7)))
    prog = ir.Let(
        "a", mk(),
        ir.Let("b", mk(), ir.MakeStruct((
            ir.Ident("a", wt.Vec(wt.I64)), ir.Ident("b", wt.Vec(wt.I64))))),
    )
    opt = _check_equiv(prog, {"v": [1, 2]})
    assert loop_count(opt) == 1


def test_pass_ablation_no_fusion():
    """Disabling fusion must keep both loops (for Fig. 10 ablations)."""
    v = _vec_ident()
    f = M.filter_(v, lambda x: ir.BinOp(">", x, M.lit(0)))
    s = M.reduce_(f, "+")
    opt = optimize(s, passes=["inline", "size", "predication", "cse"])
    assert loop_count(opt) == 2
    opt_full = optimize(s)
    assert loop_count(opt_full) == 1


def test_optimizer_fixpoint_terminates():
    v = _vec_ident()
    e = M.map_(M.map_(M.map_(v, lambda x: x), lambda x: x), lambda x: x)
    stats = {}
    opt = optimize(e, stats=stats)
    assert loop_count(opt) == 1
    assert stats["iterations"] <= 6


def test_zip_fusion_aligned_filters():
    """The paper's single-pass dataframe traversal: a zip-consumer over
    two identically-filtered columns fuses into ONE loop."""
    a = ir.Ident("a", wt.Vec(wt.I64))
    b = ir.Ident("b", wt.Vec(wt.I64))
    mask = ir.Ident("m", wt.Vec(wt.I64))

    def filt(col):
        bt = wt.VecBuilder(wt.I64)
        bb, ii, xx = (ir.Ident(ir.fresh(n), t) for n, t in
                      (("b", bt), ("i", wt.I64),
                       ("x", wt.Struct((wt.I64, wt.I64)))))
        return ir.Result(ir.For(
            (ir.Iter(col), ir.Iter(mask)), ir.NewBuilder(bt),
            ir.Lambda((bb, ii, xx), ir.If(
                ir.BinOp(">", ir.GetField(xx, 1), M.lit(0)),
                ir.Merge(bb, ir.GetField(xx, 0)), bb)),
        ))

    bt = wt.Merger(wt.I64, "+")
    bb, ii, xx = (ir.Ident(ir.fresh(n), t) for n, t in
                  (("b", bt), ("i", wt.I64),
                   ("x", wt.Struct((wt.I64, wt.I64)))))
    consumer = ir.Result(ir.For(
        (ir.Iter(filt(a)), ir.Iter(filt(b))), ir.NewBuilder(bt),
        ir.Lambda((bb, ii, xx), ir.Merge(
            bb, ir.BinOp("+", ir.GetField(xx, 0), ir.GetField(xx, 1)))),
    ))
    env = {"a": [1, 2, 3, 4], "b": [10, 20, 30, 40], "m": [1, 0, 1, 0]}
    want = interpret(consumer, env)
    shapes = {"a": (4,), "b": (4,), "m": (4,)}
    stats = {}
    opt = optimize(consumer, stats=stats, input_shapes=shapes)
    assert interpret(opt, env) == want == (1 + 10) + (3 + 30)
    assert loop_count(opt) == 1
    assert stats.get("fusion.zip", 0) >= 1


def test_zip_fusion_rejects_unknown_lengths():
    """Without static lengths, union fusion must not fire (soundness)."""
    a = ir.Ident("a", wt.Vec(wt.I64))
    b = ir.Ident("b", wt.Vec(wt.I64))
    ma = M.map_(a, lambda x: ir.BinOp("*", x, M.lit(2)))
    mb = M.map_(b, lambda x: ir.BinOp("*", x, M.lit(3)))
    bt = wt.Merger(wt.I64, "+")
    bb, ii, xx = (ir.Ident(ir.fresh(n), t) for n, t in
                  (("b", bt), ("i", wt.I64),
                   ("x", wt.Struct((wt.I64, wt.I64)))))
    consumer = ir.Result(ir.For(
        (ir.Iter(ma), ir.Iter(mb)), ir.NewBuilder(bt),
        ir.Lambda((bb, ii, xx), ir.Merge(
            bb, ir.BinOp("+", ir.GetField(xx, 0), ir.GetField(xx, 1)))),
    ))
    # different lengths: min-semantics must be preserved
    env = {"a": [1, 2, 3], "b": [10, 20]}
    want = interpret(consumer, env)
    opt_nolen = optimize(consumer)  # no shapes -> no fuse
    assert interpret(opt_nolen, env) == want == (2 + 30) + (4 + 60)
    # with equal static lengths it fuses
    stats = {}
    opt = optimize(consumer, stats=stats, input_shapes={"a": (3,), "b": (3,)})
    env_eq = {"a": [1, 2, 3], "b": [10, 20, 30]}
    assert interpret(opt, env_eq) == interpret(consumer, env_eq)
    assert loop_count(opt) == 1


def test_crime_index_fuses_to_single_pass():
    """End-to-end: the flagship workload is ONE loop after optimization."""
    import numpy as np

    from repro.core.lazy import build_program
    from repro.frames import welddf

    rng = np.random.RandomState(0)
    n = 64
    df = welddf.DataFrame({
        "population": rng.randint(0, 10**6, n).astype(np.float64),
        "crime": rng.rand(n),
    })
    big = df[df["population"] > 500_000]
    total = (big["population"] * 0.1 + big["crime"] * 2.0).sum()
    prog = build_program(total.obj)
    shapes = {k: (n,) for k in prog.inputs}
    opt = optimize(prog.expr, input_shapes=shapes)
    assert loop_count(opt) == 1
