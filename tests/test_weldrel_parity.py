"""Eager <-> lazy parity for weldrel, plus regression tests for the
eager-path bugs this PR fixes (wrong empty-input agg identities, silent
op-ignoring group_agg) and the autotune cache robustness fixes."""
import json
import os

import numpy as np
import pytest

from repro.frames import weldrel

rng = np.random.RandomState(7)

OPS = ("+", "*", "min", "max")


def _tables(cols):
    return (weldrel.Table(cols, eager=True), weldrel.Table(cols, eager=False))


def _agg_all_ops(t, **kw):
    q = weldrel.Query(t)
    return q.agg({op: (t.col("v"), op) for op in OPS}, **kw)


# ---------------------------------------------------------------------------
# agg: empty input / fully-filtered input reduce to the merger identity
# on BOTH paths (the eager path used to return 0.0 for every op)
# ---------------------------------------------------------------------------


def test_agg_empty_input_identities_match():
    te, tl = _tables({"v": np.zeros(0)})
    re_ = _agg_all_ops(te)
    rl = _agg_all_ops(tl, kernelize=False)
    assert re_["+"] == rl["+"] == 0.0
    assert re_["*"] == rl["*"] == 1.0
    assert re_["min"] == rl["min"] == np.finfo(np.float64).max
    assert re_["max"] == rl["max"] == np.finfo(np.float64).min


def test_agg_all_false_predicate_parity():
    v = rng.rand(64)
    te, tl = _tables({"v": v})
    re_ = weldrel.Query(te).filter(te.col("v") > 2.0).agg(
        {op: (te.col("v"), op) for op in OPS})
    rl = weldrel.Query(tl).filter(tl.col("v") > 2.0).agg(
        {op: (tl.col("v"), op) for op in OPS}, kernelize=False)
    for op in OPS:
        np.testing.assert_allclose(re_[op], rl[op])
    assert re_["*"] == 1.0  # not the old hardwired 0.0


def test_agg_single_and_multi_column_parity():
    a, b, p = rng.rand(257), rng.rand(257), rng.rand(257)
    te, tl = _tables({"a": a, "b": b, "p": p})

    def q(t, **kw):
        return weldrel.Query(t).filter(t.col("p") < 0.5).agg(
            {"s": (t.col("a"), "+"),
             "m": (t.col("b"), "min"),
             "x": (t.col("a") * t.col("b"), "max"),
             "pr": (t.col("b"), "*")}, **kw)

    re_ = q(te)
    rl = q(tl, kernelize=False)
    rk = q(tl, kernelize=True)
    for k in re_:
        np.testing.assert_allclose(re_[k], rl[k], rtol=1e-10)
        np.testing.assert_allclose(re_[k], rk[k], rtol=1e-10)
    mask = p < 0.5
    np.testing.assert_allclose(re_["s"], a[mask].sum(), rtol=1e-10)
    np.testing.assert_allclose(re_["m"], b[mask].min(), rtol=1e-10)


# ---------------------------------------------------------------------------
# group_agg: the eager path must enforce the same "+"-only contract as
# the lazy path instead of silently summing whatever op was requested
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eager", [True, False])
def test_group_agg_non_plus_op_raises(eager):
    t = weldrel.Table({"k": np.array([1, 1, 2], np.int64),
                       "v": np.array([1.0, 2.0, 3.0])}, eager=eager)
    with pytest.raises(AssertionError, match="sum/count"):
        weldrel.Query(t).group_agg([t.col("k")], {"v": (t.col("v"), "max")})


def test_group_agg_sum_parity():
    k = rng.randint(0, 8, 200).astype(np.int64)
    v = rng.rand(200)
    te, tl = _tables({"k": k, "v": v})
    ge = weldrel.Query(te).group_agg([te.col("k")], {"v": (te.col("v"), "+")})
    gl = weldrel.Query(tl).group_agg([tl.col("k")], {"v": (tl.col("v"), "+")},
                                     capacity=64)
    assert set(ge) == set(gl)
    for key in ge:
        np.testing.assert_allclose(ge[key][0], gl[key][0], rtol=1e-10)
        assert ge[key][1] == gl[key][1]  # implicit count


def test_group_agg_int_column_dtype_parity():
    """Integer value columns must aggregate as ints on the eager path
    (it used to seed every accumulator with 0.0 and float them) and
    decode to the same python types the lazy dict decode produces."""
    k = rng.randint(0, 5, 120).astype(np.int64)
    vi = rng.randint(0, 100, 120).astype(np.int64)
    vf = rng.rand(120)
    te, tl = _tables({"k": k, "vi": vi, "vf": vf})

    def q(t, **kw):
        return weldrel.Query(t).group_agg(
            [t.col("k")],
            {"vi": (t.col("vi"), "+"), "vf": (t.col("vf"), "+")}, **kw)

    ge = q(te)
    gl = q(tl, capacity=16)
    assert set(ge) == set(gl)
    for key in ge:
        for a, b in zip(ge[key], gl[key]):
            assert type(a) is type(b), (key, ge[key], gl[key])
            np.testing.assert_allclose(a, b, rtol=1e-10)
        assert isinstance(ge[key][0], int)    # int column stays int
        assert isinstance(ge[key][1], float)  # float column stays float
        assert isinstance(ge[key][-1], int)   # implicit count


# ---------------------------------------------------------------------------
# autotune cache: atomic writes, corrupt files tolerated with a warning
# ---------------------------------------------------------------------------


@pytest.fixture
def tuner(tmp_path, monkeypatch):
    from repro.core.kernelplan import autotune

    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "autotune.json"))
    autotune.clear_cache(disk=False)
    autotune._cache = None
    yield autotune
    autotune.clear_cache(disk=False)


def test_autotune_corrupt_cache_warns_and_recovers(tuner):
    with open(tuner.cache_path(), "w") as f:
        f.write('{"filter_reduce_sum|float64|2048|interp')  # truncated write
    with pytest.warns(RuntimeWarning, match="corrupt") as rec:
        assert tuner._load() == {}
    # the warning must point at the offending file AND carry the JSON
    # parser's error so the user knows what to inspect/delete
    msg = str(rec[0].message)
    assert tuner.cache_path() in msg
    assert "delete the file" in msg
    assert any(w in msg for w in ("Unterminated", "Expecting", "char")), msg
    # tuning proceeds and the next save replaces the bad file atomically
    from repro.core import kernelplan as kp

    spec = kp.get("filter_reduce_sum")
    params, cached = tuner.tune(spec, {"n": 1500, "dtype": np.float64},
                                impl="interpret")
    assert params["block"] in spec.tune_space["block"] and not cached
    disk = json.load(open(tuner.cache_path()))
    assert any(k.startswith("filter_reduce_sum|") for k in disk)


def test_autotune_save_is_atomic_no_temp_left(tuner):
    from repro.core import kernelplan as kp

    spec = kp.get("filter_reduce_sum")
    tuner.tune(spec, {"n": 1200, "dtype": np.float64}, impl="interpret")
    d = os.path.dirname(tuner.cache_path())
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
    json.load(open(tuner.cache_path()))  # valid JSON on disk
