"""Test-wide defaults: run the whole suite with weldcheck on.

``WELD_VERIFY=1`` makes every compile re-verify the IR after each
optimizer pass, after kernel planning, and after every recovery
rewrite — so any pass producing ill-typed/non-linear/racy IR fails the
suite loudly even when the miscompiled program happens to produce the
right numbers.  Explicitly exported ``WELD_VERIFY=0`` wins (for
overhead A/B runs).
"""
import os

os.environ.setdefault("WELD_VERIFY", "1")
