"""Test-wide defaults: run the whole suite with weldcheck on.

``WELD_VERIFY=1`` makes every compile re-verify the IR after each
optimizer pass, after kernel planning, and after every recovery
rewrite — so any pass producing ill-typed/non-linear/racy IR fails the
suite loudly even when the miscompiled program happens to produce the
right numbers.  Explicitly exported ``WELD_VERIFY=0`` wins (for
overhead A/B runs).

``WELD_COST_LEDGER`` defaults to a per-session temp file: the cost
gate calibrates itself from ledger medians, so a developer's real
ledger (with honest-but-slow CPU timings) would silently flip routing
decisions the suite asserts on.  Explicitly exported paths win.
"""
import os
import tempfile

os.environ.setdefault("WELD_VERIFY", "1")
os.environ.setdefault(
    "WELD_COST_LEDGER",
    os.path.join(tempfile.mkdtemp(prefix="weld-test-"), "ledger.jsonl"))
