"""Property-based tests (hypothesis) on the system's invariants:

1. optimizer + JAX backend ≡ pure-Python reference interpreter on random
   Weld programs composed from the macro vocabulary;
2. builder merges are order-insensitive for commutative mergers;
3. fusion never changes the number/type of results;
4. predication preserves filter+reduce semantics for every MERGE_OP.
"""
import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis is an optional extra; every test here is a "
           "property sweep, so the whole module skips without it",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ir, macros as M, wtypes as wt
from repro.core.interp import interpret
from repro.core.lazy import Evaluate, NewWeldObject
from repro.core.passes import loop_count, optimize

ints = st.integers(min_value=-100, max_value=100)
vec_data = st.lists(ints, min_size=1, max_size=30)


def _obj(arr):
    return NewWeldObject(np.asarray(arr, dtype=np.int64), None)


def _id(o):
    return ir.Ident(o.obj_id, o.weld_type())


# -- random program generator -------------------------------------------------

_unary_int_ops = ["neg", "abs"]


@st.composite
def pipelines(draw):
    """A random chain of map/filter stages ending in a reduce or map."""
    n_stages = draw(st.integers(1, 4))
    stages = []
    for _ in range(n_stages):
        kind = draw(st.sampled_from(["map_add", "map_mul", "map_abs",
                                     "filter_gt", "filter_even"]))
        c = draw(st.integers(-20, 20))
        stages.append((kind, c))
    final = draw(st.sampled_from(["sum", "max", "min", "none"]))
    return stages, final


def _build(stages, final, src_expr):
    e = src_expr
    for kind, c in stages:
        if kind == "map_add":
            e = M.map_(e, lambda x, c=c: ir.BinOp("+", x, M.lit(c)))
        elif kind == "map_mul":
            # keep magnitudes bounded to avoid overflow differences
            e = M.map_(e, lambda x, c=c: ir.BinOp("*", x, M.lit(c % 3)))
        elif kind == "map_abs":
            e = M.map_(e, lambda x: ir.UnaryOp("abs", x))
        elif kind == "filter_gt":
            e = M.filter_(e, lambda x, c=c: ir.BinOp(">", x, M.lit(c)))
        elif kind == "filter_even":
            e = M.filter_(
                e, lambda x: ir.BinOp(
                    "==", ir.BinOp("%", x, M.lit(2)), M.lit(0))
            )
    if final == "sum":
        e = M.reduce_(e, "+")
    elif final == "max":
        e = M.reduce_(e, "max")
    elif final == "min":
        e = M.reduce_(e, "min")
    return e


@settings(max_examples=60, deadline=None)
@given(data=vec_data, prog=pipelines())
def test_optimizer_and_backend_match_interpreter(data, prog):
    stages, final = prog
    data = [abs(d) for d in data]  # avoid C-vs-python %-semantics on negatives
    src = ir.Ident("v", wt.Vec(wt.I64))
    expr = _build(stages, final, src)

    expected = interpret(expr, {"v": list(data)})
    # optimizer preserves interpreter semantics
    got_opt = interpret(optimize(expr), {"v": list(data)})
    assert got_opt == expected

    # JAX backend (optimized) matches too
    d = _obj(data)
    expr2 = _build(stages, final, _id(d))
    obj = NewWeldObject([d], expr2)
    out = Evaluate(obj).value
    if isinstance(expected, list):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))
    else:
        assert int(out) == expected


@settings(max_examples=40, deadline=None)
@given(data=st.lists(ints, min_size=1, max_size=40),
       op=st.sampled_from(["+", "min", "max"]),
       seed=st.integers(0, 2**31 - 1))
def test_merge_order_insensitive(data, op, seed):
    """Builders are associative/commutative: any merge order gives the
    same result (the property that makes them parallelizable)."""
    rngl = np.random.RandomState(seed)
    perm = rngl.permutation(len(data))
    bt = wt.Merger(wt.I64, op)

    def run(order):
        b = ir.NewBuilder(bt)
        e = b
        for i in order:
            e = ir.Merge(e, M.lit(int(data[i])))
        return interpret(ir.Result(e))

    assert run(range(len(data))) == run(perm)


@settings(max_examples=40, deadline=None)
@given(data=vec_data, thresh=ints, op=st.sampled_from(["+", "min", "max", "*"]))
def test_predication_equivalence_all_ops(data, thresh, op):
    if op == "*":
        data = [d % 3 for d in data]  # bound products
    v = ir.Ident("v", wt.Vec(wt.I64))
    e = M.filter_reduce(v, lambda x: ir.BinOp(">", x, M.lit(thresh)), op)
    env = {"v": list(data)}
    assert interpret(optimize(e), env) == interpret(e, env)


@settings(max_examples=30, deadline=None)
@given(data=vec_data)
def test_fusion_reduces_loop_count_monotonically(data):
    v = ir.Ident("v", wt.Vec(wt.I64))
    e = M.reduce_(M.map_(M.map_(v, lambda x: ir.BinOp("+", x, M.lit(1))),
                         lambda x: ir.BinOp("*", x, M.lit(2))), "+")
    opt = optimize(e)
    assert loop_count(opt) <= loop_count(e)
    assert loop_count(opt) == 1
    env = {"v": list(data)}
    assert interpret(opt, env) == interpret(e, env)


@settings(max_examples=25, deadline=None)
@given(keys=st.lists(st.integers(0, 9), min_size=1, max_size=30),
       seed=st.integers(0, 2**31 - 1))
def test_dictmerger_matches_python_dict(keys, seed):
    rngl = np.random.RandomState(seed)
    vals = rngl.randint(-50, 50, size=len(keys)).astype(np.int64)
    k = NewWeldObject(np.asarray(keys, dtype=np.int64), None)
    v = NewWeldObject(vals, None)
    e = M.groupby_agg(_id(k), _id(v), "+", capacity=32)
    out = Evaluate(NewWeldObject([k, v], e)).value
    want: dict = {}
    for kk, vv in zip(keys, vals):
        want[kk] = want.get(kk, 0) + int(vv)
    assert out == want


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 20), m=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_vecmerger_scatter_matches_numpy(n, m, seed):
    rngl = np.random.RandomState(seed)
    idx = rngl.randint(0, m, size=n)
    vals = rngl.rand(n)
    base = np.zeros(m)
    b = NewWeldObject(base, None)
    i = NewWeldObject(idx.astype(np.int64), None)
    v = NewWeldObject(vals, None)
    e = M.scatter_add(_id(b), _id(i), _id(v))
    out = Evaluate(NewWeldObject([b, i, v], e)).value
    want = base.copy()
    np.add.at(want, idx, vals)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-12)
