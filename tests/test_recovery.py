"""Adaptive recovery runtime: typed errors, fault injection, the
poison-triggered retry ladder, and kernel quarantine.

Every test arms deterministic failpoints (``repro.faults``) to reach
degradation paths that are unreachable on healthy inputs, then asserts
the documented contract: results stay oracle-correct, every step is
observable (RuntimeWarning + ``recovery.*`` stats + weldtrace spans),
and with recovery disabled the typed exception surfaces instead.
"""
import warnings

import numpy as np
import pytest

from repro.core import faults, obs, recovery, runtime
from repro.core.errors import (
    CapacityError, InjectedFault, KernelCompileError, ResourceError,
    WeldError,
)
from repro.core.kernelplan import quarantine
from repro.frames.weldrel import Query, Table

rng = np.random.RandomState(7)


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    """Every test gets a private health file, disarmed faults, a cold
    compile cache, and tmp-dir autotune/ledger artifacts."""
    monkeypatch.setenv(quarantine.ENV_FILE,
                       str(tmp_path / "kernel_health.json"))
    monkeypatch.setenv("WELD_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.setenv("WELD_COST_LEDGER", str(tmp_path / "ledger.jsonl"))
    quarantine.clear(disk=False)
    faults.clear()
    runtime.clear_cache()
    yield
    faults.clear()
    quarantine.clear(disk=False)
    runtime.clear_cache()


# ---------------------------------------------------------------------------
# typed exception hierarchy (satellite: repro.errors)
# ---------------------------------------------------------------------------


def test_error_hierarchy_contracts():
    import repro.errors as top

    assert issubclass(WeldError, RuntimeError)
    # CapacityError must satisfy BOTH historical catch sites: decode
    # poison raised RuntimeError, the join capacity guard ValueError
    assert issubclass(CapacityError, WeldError)
    assert issubclass(CapacityError, ValueError)
    assert issubclass(ResourceError, WeldError)
    assert issubclass(KernelCompileError, WeldError)
    assert issubclass(InjectedFault, WeldError)
    for name in ("WeldError", "CapacityError", "ResourceError",
                 "KernelCompileError", "InjectedFault"):
        assert getattr(top, name) is globals()[name]
    e = KernelCompileError("boom", kernel="hash_probe", impl="pallas",
                           dtype="f8", n=4096)
    assert (e.kernel, e.impl, e.dtype, e.n) == ("hash_probe", "pallas",
                                                "f8", 4096)


def test_jaxgen_memory_error_is_resource_error():
    from repro.core.backend.jaxgen import WeldMemoryError

    assert WeldMemoryError is ResourceError


# ---------------------------------------------------------------------------
# fault-injection mechanics (satellite: repro.faults)
# ---------------------------------------------------------------------------


def test_fault_spec_env_parsing(monkeypatch):
    import repro.faults as top

    assert top.inject is faults.inject
    monkeypatch.setenv(faults.ENV_FAULTS,
                       "kernel.hash_probe:raise@2, dict.build:poison,"
                       "join.capacity:cap=7@3")
    monkeypatch.setattr(faults, "_armed", None)  # force env re-read
    armed = faults.armed()
    assert armed["kernel.hash_probe"][0] == {
        "action": "raise", "value": None, "remaining": 2}
    assert armed["dict.build"][0]["remaining"] == 1
    assert armed["join.capacity"][0] == {
        "action": "cap", "value": 7, "remaining": 3}
    monkeypatch.setattr(faults, "_armed", None)
    monkeypatch.setenv(faults.ENV_FAULTS, "garbage-no-colon")
    with pytest.raises(ValueError, match="site:action"):
        faults.armed()
    monkeypatch.setattr(faults, "_armed", None)
    monkeypatch.setenv(faults.ENV_FAULTS, "x:frobnicate")
    with pytest.raises(ValueError, match="unknown fault action"):
        faults.armed()
    monkeypatch.setattr(faults, "_armed", None)
    monkeypatch.delenv(faults.ENV_FAULTS)


def test_fault_consumption_and_fingerprint():
    assert faults.fingerprint() == ""  # unarmed: no cache-key pollution
    faults.inject("decode", "raise", times=2)
    fp0 = faults.fingerprint()
    assert "decode:raise@2" in fp0
    with pytest.raises(InjectedFault, match="fault injected at decode"):
        faults.maybe_raise("decode")
    assert faults.fingerprint() != fp0  # remaining count is in the key
    faults.maybe_raise("io.test-site")  # unarmed site: no-op
    with pytest.raises(InjectedFault):
        faults.maybe_raise("decode")
    faults.maybe_raise("decode")  # spent: no-op
    assert faults.fingerprint() == ""
    assert [f["site"] for f in faults.fired()] == ["decode", "decode"]
    # exc= substitutes the class at best-effort IO sites
    faults.inject("io.ledger", "raise")
    with pytest.raises(OSError):
        faults.maybe_raise("io.ledger", exc=OSError)


# ---------------------------------------------------------------------------
# the recovery ladder
# ---------------------------------------------------------------------------


def _join_tables():
    L = Table({"k": np.array([1, 2, 2, 3, 3, 3], dtype=np.int64),
               "a": np.array([10.0, 20, 21, 30, 31, 32])})
    R = Table({"k": np.array([2, 2, 3, 5], dtype=np.int64),
               "b": np.array([1.0, 2, 3, 4])})
    return L, R


def _rowset(t):
    cols = sorted(t.cols)
    arrs = [np.asarray(t.cols[c].to_numpy()) for c in cols]
    return {tuple(str(a[i]) for a in arrs) for i in range(len(arrs[0]))}


def test_mn_join_capacity_fault_recovers_to_oracle():
    """An injected undersized build capacity poisons the m:n group
    build; the ladder regrows it and the final rows match the
    un-faulted run (the pandas-oracle shape, see test_join_fuzz)."""
    L, R = _join_tables()
    want = _rowset(Query(L).join(R, on="k", kernelize="always"))
    runtime.clear_cache()
    faults.inject("join.capacity", "cap", times=1, value=1)
    st: dict = {}
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = Query(L).join(R, on="k", kernelize="always", collect_stats=st)
    assert _rowset(got) == want
    assert st["recovery.attempts"] >= 2
    assert all(e["action"] == "regrow" for e in st["recovery.events"])
    assert st["recovery.regrow_factor"] >= 2
    assert not st["recovery.fallback"]
    assert any("weld recovery" in str(x.message) for x in w)
    assert faults.fired()[0]["site"] == "join.capacity"


def test_recovery_disabled_surfaces_typed_capacity_error():
    L, R = _join_tables()
    faults.inject("join.capacity", "cap", times=1, value=1)
    with recovery.disabled():
        with pytest.raises(CapacityError):
            Query(L).join(R, on="k", kernelize="always")
    assert recovery.enabled()  # context manager restored the default


def test_recovery_env_knob(monkeypatch):
    try:
        monkeypatch.setenv(recovery.ENV_RECOVERY, "off")
        assert not recovery.enabled()
        monkeypatch.setenv(recovery.ENV_RECOVERY, "1")
        assert recovery.enabled()
        recovery.set_enabled(False)
        assert not recovery.enabled()
        recovery.set_enabled(None)  # back to the env
        assert recovery.enabled()
    finally:
        recovery.set_enabled(None)


def test_injected_decode_poison_recovers_then_exhausts():
    """A decode-site poison is indistinguishable from a real capacity
    poison; one armed hit is absorbed by the retry, while a hit armed
    beyond the ladder's depth exhausts it into a typed error."""
    L, R = _join_tables()
    faults.inject("decode", "poison", times=1)
    st: dict = {}
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        got = Query(L).join(R, on="k", kernelize="off", collect_stats=st)
    assert st["recovery.attempts"] == 2
    assert len(_rowset(got)) == 7
    faults.clear()
    runtime.clear_cache()
    faults.inject("decode", "poison", times=99)  # deeper than the ladder
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        with pytest.raises(CapacityError, match="recovery exhausted"):
            Query(L).join(R, on="k", kernelize="off")


def test_explain_analyze_shows_recovery():
    L, R = _join_tables()
    faults.inject("join.capacity", "cap", times=1, value=1)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        rep = Query(L).explain(analyze=True).join(R, on="k",
                                                  kernelize="always")
    txt = rep.render()
    assert "-- recovery --" in txt
    assert "recovered after" in txt
    assert "regrow" in txt
    assert any(sp.name == "recovery.retry" for sp in rep.spans)
    assert any(sp.name == "recovery.step" for sp in rep.spans)
    assert _rowset(rep.result) == _rowset(
        Query(L).join(R, on="k", kernelize="always"))


# ---------------------------------------------------------------------------
# kernel quarantine
# ---------------------------------------------------------------------------


def test_kernel_fault_degrades_quarantines_and_gates(tmp_path):
    """A kernel launch failure falls back to the generic lowering of the
    SAME program, records the offender on disk, and the next compile
    rejects the quarantined route at the cost gate — without a cache
    clear, proving the quarantine fingerprint invalidates the compile
    cache."""
    L, R = _join_tables()
    want = _rowset(Query(L).join(R, on="k", kernelize="off"))
    qfp0 = quarantine.fingerprint()
    faults.inject("kernel.group_build", "raise", times=1)
    st: dict = {}
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = Query(L).join(R, on="k", kernelize="always", collect_stats=st)
    assert _rowset(got) == want
    assert st["recovery.fallback"]
    assert st["recovery.events"][0]["action"] == "quarantine"
    assert st["recovery.quarantined"]
    assert any("quarantined" in str(x.message) for x in w)
    key = st["recovery.quarantined"][0]
    assert key.startswith("group_build|")
    assert quarantine.is_quarantined("group_build", impl=key.split("|")[1],
                                     dtype=key.split("|")[2], n=1)
    assert (tmp_path / "kernel_health.json").exists()
    assert quarantine.entries()[key]["count"] == 1
    assert "InjectedFault" in quarantine.entries()[key]["last_error"]
    assert quarantine.fingerprint() != qfp0
    # next compile (NO cache clear): the gate rejects the offender up
    # front; the probe kernel is untainted and may still route
    st2: dict = {}
    got2 = Query(L).join(R, on="k", kernelize="always", collect_stats=st2)
    assert _rowset(got2) == want
    kp = st2["kernelplan"]
    assert kp["rejected"].get("group_build") == 1
    assert any(c.get("why") == "quarantined" and not c.get("routed")
               for c in kp["costs"])
    assert "group_build" not in kp.get("routed", {})
    assert "recovery.attempts" not in st2  # healthy run: ladder untouched


def test_quarantine_corrupt_file_degrades_to_empty(tmp_path, monkeypatch):
    p = tmp_path / "kernel_health.json"
    p.write_text("{not json")
    monkeypatch.setattr(quarantine, "_health", None)  # force re-read
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert quarantine.entries() == {}
    hits = [x for x in w if "corrupt" in str(x.message)]
    assert hits, [str(x.message) for x in w]
    # warning parity with the recovery ladder's other RuntimeWarnings:
    # typed, names the offending path, carries the parser error, and
    # tells the user the remedy
    (warning,) = hits
    assert issubclass(warning.category, RuntimeWarning)
    msg = str(warning.message)
    assert str(p) in msg
    assert "Expecting" in msg or "not an object" in msg  # parser detail
    assert "delete the file" in msg
    # a non-dict JSON root takes the same degrade path
    p.write_text("[1, 2, 3]")
    monkeypatch.setattr(quarantine, "_health", None)
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        assert quarantine.entries() == {}
    assert any("not an object" in str(x.message) for x in w2)


def test_quarantine_io_fault_is_best_effort(tmp_path):
    faults.inject("io.quarantine", "raise", times=1)
    quarantine.record("hash_probe", impl="pallas", dtype="f8", n=100,
                      error="x")
    # the write failed, but the quarantine still applies in-process
    assert not (tmp_path / "kernel_health.json").exists()
    assert quarantine.is_quarantined("hash_probe", impl="pallas",
                                     dtype="f8", n=100)
    quarantine.record("hash_probe", impl="pallas", dtype="f8", n=100)
    assert (tmp_path / "kernel_health.json").exists()


# ---------------------------------------------------------------------------
# generic-path overflow parity (satellite: no silent truncation)
# ---------------------------------------------------------------------------


def test_generic_build_overflow_poisons_not_truncates():
    """The generic dictmerger used to silently drop groups past its
    capacity; it must now flag the same negative-count poison the
    kernels do — recovered to the full result, or a typed error."""
    from repro.core import ir, macros as M
    from repro.core.lazy import Evaluate, NewWeldObject

    vals_np = rng.rand(100)

    def mk(capacity):
        keys = NewWeldObject(np.arange(100, dtype=np.int64), None)
        vals = NewWeldObject(vals_np, None)
        kid = ir.Ident(keys.obj_id, keys.weld_type())
        vid = ir.Ident(vals.obj_id, vals.weld_type())
        return NewWeldObject([keys, vals],
                             M.groupby_agg(kid, vid, "+", capacity=capacity))

    want = Evaluate(mk(256), kernelize="off").value
    assert len(want) == 100
    st: dict = {}
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        got = Evaluate(mk(32), kernelize="off", collect_stats=st).value
    assert st["recovery.attempts"] >= 2  # 32 -> 64 -> 128
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-10)
    with recovery.disabled():
        with pytest.raises(CapacityError,
                           match="poisoned|distinct|capacity"):
            Evaluate(mk(32), kernelize="off")


def test_kernel_generic_overflow_parity():
    """Same undersized program, kernel and generic routes: both poison,
    both recover to identical results."""
    from repro.core import ir, macros as M
    from repro.core.lazy import Evaluate, NewWeldObject

    keys_np = (np.arange(300, dtype=np.int64) % 150) * 2
    vals_np = rng.rand(300)

    def mk():
        keys = NewWeldObject(keys_np, None)
        vals = NewWeldObject(vals_np, None)
        kid = ir.Ident(keys.obj_id, keys.weld_type())
        vid = ir.Ident(vals.obj_id, vals.weld_type())
        return NewWeldObject([keys, vals],
                             M.groupby_agg(kid, vid, "+", capacity=64))

    outs = {}
    for mode in ("always", "off"):
        st: dict = {}
        with warnings.catch_warnings(record=True):
            warnings.simplefilter("always")
            outs[mode] = Evaluate(mk(), kernelize=mode,
                                  collect_stats=st).value
        assert st["recovery.attempts"] >= 2, mode
    assert set(outs["always"]) == set(outs["off"])
    assert len(outs["always"]) == 150
    for k in outs["off"]:
        np.testing.assert_allclose(outs["always"][k], outs["off"][k],
                                   rtol=1e-10)


# ---------------------------------------------------------------------------
# best-effort observability paths (satellite: measured-replay tagging)
# ---------------------------------------------------------------------------


def test_measured_replay_failure_is_tagged_not_raised(tmp_path):
    """An injected failure inside the traced eager replay must land on
    the measure.replay span as error=..., never propagate, and write no
    bogus ledger record."""
    from repro.core.obs import ledger

    L, R = _join_tables()
    faults.inject("measure.replay", "raise", times=1)
    was_on = obs.enabled()
    obs.enable()
    pos = obs.mark()
    try:
        got = Query(L).join(R, on="k", kernelize="always")
    finally:
        if not was_on:
            obs.disable()
    assert len(_rowset(got)) == 7  # the fault never reached the caller
    spans = obs.spans_since(pos)
    replay = [sp for sp in spans if sp.name == "measure.replay"]
    assert replay and "InjectedFault" in replay[0].tags["error"]
    assert ledger.read(str(tmp_path / "ledger.jsonl")) == []


def test_ledger_io_fault_drops_record_not_execution(tmp_path):
    from repro.core.obs import ledger

    faults.inject("io.ledger", "raise", times=1)
    p = str(tmp_path / "ledger.jsonl")
    assert ledger.record("k", "f8", 10, 1, 2, path=p) is None
    assert ledger.read(p) == []
    assert ledger.record("k", "f8", 10, 1, 2, path=p) is not None
    assert len(ledger.read(p)) == 1
