"""Training-loop integration tests: loss decreases, optimizer impls
agree, checkpoint preempt/resume is bitwise-identical, pipeline is
deterministic and shard-stable, straggler monitor fires."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import TokenPipeline

pytestmark = pytest.mark.slow  # full train/resume loops: ~30 s
from repro.distributed.straggler import StepMonitor
from repro.kernels import ref as kref
from repro.launch.train import train
from repro.optim import adamw_init, adamw_update_tree
from repro.optim.adamw import adamw_update_weld


def test_loss_decreases():
    out = train("llama3.2-3b", smoke=True, steps=60, global_batch=8,
                seq_len=32, peak_lr=3e-3, verbose=False)
    first = np.mean(out["losses"][:10])
    last = np.mean(out["losses"][-10:])
    assert last < first - 0.05, (first, last)


def test_grad_accumulation_matches_large_batch():
    o1 = train("llama3.2-3b", smoke=True, steps=5, global_batch=8,
               seq_len=16, accum=1, verbose=False)
    o2 = train("llama3.2-3b", smoke=True, steps=5, global_batch=8,
               seq_len=16, accum=4, verbose=False)
    np.testing.assert_allclose(o1["losses"], o2["losses"], rtol=1e-4)


def test_preempt_resume_bitwise(tmp_path):
    """Kill at step 10, resume, final params equal the uninterrupted run."""
    d1 = str(tmp_path / "a")
    full = train("llama3.2-3b", smoke=True, steps=20, global_batch=4,
                 seq_len=16, ckpt_dir=d1, ckpt_every=100, verbose=False)

    d2 = str(tmp_path / "b")
    train("llama3.2-3b", smoke=True, steps=10, global_batch=4,
          seq_len=16, ckpt_dir=d2, ckpt_every=10, verbose=False)
    resumed = train("llama3.2-3b", smoke=True, steps=20, global_batch=4,
                    seq_len=16, ckpt_dir=d2, ckpt_every=10, resume=True,
                    verbose=False)
    for a, b in zip(jax.tree_util.tree_leaves(full["params"]),
                    jax.tree_util.tree_leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_integrity_detection(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.arange(10, dtype=jnp.float32)}
    ck.save(1, state, blocking=True)
    # corrupt the file
    import glob
    import os
    f = glob.glob(str(tmp_path / "step_1" / "*.npy"))[0]
    arr = np.load(f)
    arr_bad = arr.copy()
    arr_bad[0] += 1
    np.save(f, arr_bad)
    with pytest.raises(IOError):
        ck.restore(1, state)


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"w": jnp.full((4,), s, jnp.float32)})
    ck.wait()
    assert ck.list_steps() == [3, 4]
    got, extra = ck.restore(4, {"w": jnp.zeros((4,), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.full(4, 4.0))


def test_pipeline_shard_stability():
    """Global stream is identical regardless of shard layout."""
    full = TokenPipeline(vocab=97, seq_len=16, global_batch=8)
    b_full = full.next_batch()
    shards = []
    for k in range(4):
        p = TokenPipeline(vocab=97, seq_len=16, global_batch=8,
                          shard=k, num_shards=4)
        shards.append(p.next_batch())
    merged = np.concatenate([s["tokens"] for s in shards], axis=0)
    np.testing.assert_array_equal(merged, b_full["tokens"])


def test_pipeline_state_roundtrip():
    p = TokenPipeline(vocab=97, seq_len=8, global_batch=2)
    p.next_batch()
    p.next_batch()
    st = p.state()
    b3 = p.next_batch()
    q = TokenPipeline(vocab=97, seq_len=8, global_batch=2)
    q.restore(st)
    np.testing.assert_array_equal(q.next_batch()["tokens"], b3["tokens"])


def test_pipeline_weld_preprocess():
    p = TokenPipeline(vocab=50, seq_len=8, global_batch=2)
    raw = np.array([[1, 0, 3], [0, 5, 6]], dtype=np.int64)
    toks, mask = p.preprocess_weld(raw, pad_id=0)
    np.testing.assert_array_equal(toks, raw)
    np.testing.assert_array_equal(mask, np.array([[1, 0, 1], [0, 1, 1]]))


def test_adamw_weld_matches_jax():
    rng = np.random.RandomState(0)
    n = 512
    p = rng.randn(n)
    g = rng.randn(n) * 0.1
    m = np.zeros(n)
    v = np.zeros(n)
    wp, wm, wv = adamw_update_weld(p, g, m, v, 1e-3, 1.0)
    rp, rm, rv = kref.adamw_update(
        jnp.asarray(p), jnp.asarray(g), jnp.asarray(m), jnp.asarray(v),
        1e-3, 1.0)
    np.testing.assert_allclose(np.asarray(wp), np.asarray(rp), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(wm), np.asarray(rm), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(wv), np.asarray(rv), rtol=1e-6)


def test_adamw_tree_pallas_matches_jax():
    rng = np.random.RandomState(1)
    params = {"a": jnp.asarray(rng.randn(64, 8), jnp.float32),
              "b": jnp.asarray(rng.randn(32), jnp.float32)}
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(np.full(p.shape, 0.01), jnp.float32), params)
    o1 = adamw_init(params)
    o2 = adamw_init(params)
    p1, _ = adamw_update_tree(params, grads, o1, 1e-3, impl="jax")
    p2, _ = adamw_update_tree(params, grads, o2, 1e-3, impl="pallas")
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5,
                                   atol=1e-7)


def test_straggler_monitor_flags_outliers():
    mon = StepMonitor(threshold=2.0, patience=2)
    for i in range(12):
        mon.start()
        time.sleep(0.012 if i in (8, 9) else 0.002)
        mon.stop()
    assert len(mon.events) >= 2
    assert mon.escalations >= 1
    s = mon.summary()
    assert s["steps"] == 12 and s["stragglers"] >= 2


def test_serve_greedy_decode():
    from repro.launch.serve import serve
    out = serve("llama3.2-3b", smoke=True, batch=2, prompt_len=8,
                gen_len=8, verbose=False)
    assert out["tokens"].shape == (2, 8)
    assert out["tok_per_s"] > 0
