"""weldbound: symbolic size/memory-bounds inference + admission control.

Four layers:

1. the symbolic domain (folding, evaluation, rendering, intervals);
2. interpreter transfer functions on hand-built IR (map / filter /
   dict build / m:n expansion);
3. whole-plan artifacts on real weldrel pipelines — certificates in
   stats, the ``-- bounds --`` explain section, soundness of derived
   intervals against observed output sizes;
4. consumers — compile-time admission control (typed ResourceError,
   zero launches), the recovery ladder's capacity clamp, and the
   ``WELD_BOUNDS`` kill switch.
"""
import numpy as np
import pytest

from repro.core import ir, obs, recovery, wtypes as wt
from repro.core.analysis import bounds, domain as d
from repro.core.errors import ResourceError
from repro.frames import weldrel


# ---------------------------------------------------------------------------
# domain
# ---------------------------------------------------------------------------


def test_sym_folding_and_identities():
    n = d.length("xs")
    assert d.add(d.const(2), d.const(3)) == d.const(5)
    assert d.mul(d.const(1), n) == n
    assert d.mul(d.const(0), n) == d.const(0)
    assert d.add(d.const(0), n) == n
    assert d.smax(n, d.const(0)) == n  # lengths are nonnegative
    assert d.smin(d.const(4), d.const(9)) == d.const(4)


def test_sym_evaluate_against_shapes():
    s = d.mul(d.add(d.length("a"), d.const(2)), d.const(8))
    assert d.evaluate(s, {"a": (10,)}) == 96
    assert d.evaluate(s, {}) is None  # unknown length: unresolvable
    assert d.evaluate(d.div(d.const(7), d.const(0)), {}) == 0


def test_sym_render_is_readable():
    s = d.mul(d.length("obj123"), d.smax(d.length("obj9"), d.const(1)))
    txt = d.render(s, {"obj123": "in0", "obj9": "in1"})
    assert txt == "len(in0)*max(len(in1), 1)"


def test_interval_arithmetic_and_values():
    a = d.Interval(d.const(2), d.const(5))
    b = d.Interval(d.const(0), d.length("xs"))
    m = a.mul(b)
    assert m.lo_val({}) == 0
    assert m.hi_val({"xs": (3,)}) == 15
    assert a.join(b).lo_val({}) == 0
    assert b.hi_val({}) == d.INF


def test_sym_of_mirrors_static_eval_fragment():
    xs = ir.Ident("xs", wt.Vec(wt.F64))
    e = ir.BinOp("*", ir.Len(xs), ir.Literal(8, wt.I64))
    assert bounds.static_size(e, {"xs": (11,)}) == 88
    assert bounds.static_size(e, {}) is None
    # outside the emitter's static fragment: None, not a guess
    assert bounds.sym_of(ir.UnaryOp("not", ir.Literal(True, wt.Bool))) \
        is None


# ---------------------------------------------------------------------------
# interpreter transfer functions
# ---------------------------------------------------------------------------

XS = ir.Ident("xs", wt.Vec(wt.F64))


def _loop(body_fn, init=None):
    vbt = wt.VecBuilder(wt.F64)
    b, i, e = (ir.Ident("b", vbt), ir.Ident("i", wt.I64),
               ir.Ident("e", wt.F64))
    return ir.Result(ir.For(
        (ir.Iter(XS),),
        init if init is not None else ir.NewBuilder(vbt),
        ir.Lambda((b, i, e), body_fn(b, i, e))))


def test_map_bounds_are_exact():
    prog = _loop(lambda b, i, e: ir.Merge(b, e))
    rep = bounds.analyze(prog)
    lo, hi = rep.result_rows({"xs": (42,)})
    assert (lo, hi) == (42, 42)


def test_filter_bounds_are_zero_to_n():
    prog = _loop(lambda b, i, e: ir.If(
        ir.BinOp(">", e, ir.Literal(0.0, wt.F64)), ir.Merge(b, e), b))
    rep = bounds.analyze(prog)
    assert rep.result_rows({"xs": (42,)}) == (0, 42)
    # and symbolically: hi is len(xs), not a constant
    iv = rep.result_interval()
    assert iv.hi_val({}) == d.INF or iv.hi == d.length("xs")


def test_dict_build_bounds_min_of_n_and_capacity():
    bty = wt.DictMerger(wt.I64, wt.F64, "+")
    b, i, e = (ir.Ident("b", bty), ir.Ident("i", wt.I64),
               ir.Ident("e", wt.F64))
    prog = ir.Result(ir.For(
        (ir.Iter(XS),),
        ir.NewBuilder(bty, arg=ir.Literal(16, wt.I64)),
        ir.Lambda((b, i, e),
                  ir.Merge(b, ir.MakeStruct((ir.Cast(e, wt.I64), e))))))
    rep = bounds.analyze(prog)
    # distinct keys <= min(n, capacity)
    assert rep.result_rows({"xs": (100,)}) == (0, 16)
    assert rep.result_rows({"xs": (7,)}) == (0, 7)
    (bb,) = rep.builders
    assert bb.role == "cap" and bb.kind == "dictmerger"
    # rows (merge mass) is exactly n — the regrow ladder's upper clamp
    assert rep.capacity_bounds({"xs": (100,)})[id(bb.node)] == (1, 100)


def test_constant_vector_loop_needs_no_shapes():
    mv = ir.MakeVec(tuple(ir.Literal(float(k), wt.F64)
                          for k in range(5)), wt.F64)
    vbt = wt.VecBuilder(wt.F64)
    b, i, e = (ir.Ident("b", vbt), ir.Ident("i", wt.I64),
               ir.Ident("e", wt.F64))
    prog = ir.Result(ir.For(
        (ir.Iter(mv),), ir.NewBuilder(vbt),
        ir.Lambda((b, i, e), ir.Merge(b, e))))
    assert bounds.analyze(prog).result_rows({}) == (5, 5)


# ---------------------------------------------------------------------------
# whole-plan artifacts on real pipelines
# ---------------------------------------------------------------------------


def _mat(table, col):
    c = table.cols[col]
    return c._eager if c.is_eager else np.asarray(c.obj.data)


@pytest.fixture()
def join_tables():
    rng = np.random.RandomState(3)
    left = weldrel.Table({"k": rng.randint(0, 16, 256).astype(np.int64),
                          "lv": rng.rand(256)})
    mn = weldrel.Table({"k": rng.randint(0, 16, 48).astype(np.int64),
                        "rv": rng.rand(48)})
    return left, mn


def test_stats_carry_certificate_and_intervals(join_tables):
    left, mn = join_tables
    st = {}
    out = weldrel.Query(left).join(mn, on="k", collect_stats=st)
    assert "bounds.certificate" in st
    assert st["bounds.admitted"] is True
    assert st["bounds.peak_bytes"] >= 0
    lo, hi = st["bounds.out_rows"]
    observed = _mat(out, "k").size
    assert lo <= observed <= (hi if hi is not None else observed)


def test_mn_soundness_observed_inside_interval(join_tables):
    """The m:n expansion's derived interval must contain the observed
    output size — for inner (lo=0) and left (lo=n_probe) alike."""
    left, mn = join_tables
    for how in ("inner", "left"):
        st = {}
        out = weldrel.Query(left).join(mn, on="k", how=how,
                                       collect_stats=st)
        rep = bounds.analyze(st["plan.ir"])
        shapes = st["plan.inputs"][2]
        lo, hi = rep.result_rows(shapes)
        observed = _mat(out, "k").size
        assert lo <= observed, (how, lo, observed)
        assert hi is None or observed <= hi, (how, observed, hi)
        if how == "left":
            assert lo >= 256  # every probe row emits at least once


def test_explain_precount_false_shows_symbolic_certificate(join_tables):
    left, mn = join_tables
    rep = weldrel.Query(left).explain().join(mn, on="k", how="left",
                                             precount=False)
    txt = rep.render()
    assert "-- bounds --" in txt
    i = txt.index("-- bounds --")
    sect = txt[i:]
    assert "peak-memory certificate" in sect
    assert "len(" in sect  # symbolic in the input lengths
    assert "admitted=True" in sect
    assert "out_rows in [" in sect


def test_precount_false_matches_precount_true(join_tables):
    left, mn = join_tables
    for how in ("inner", "left"):
        a = weldrel.Query(left).join(mn, on="k", how=how, precount=False)
        b = weldrel.Query(left).join(mn, on="k", how=how)
        for c in ("k", "lv", "rv"):
            np.testing.assert_array_equal(_mat(a, c), _mat(b, c))


def test_precount_false_rejects_unsupported_shapes(join_tables):
    left, mn = join_tables
    fleft = weldrel.Table({"k": np.arange(8).astype(np.float64),
                           "lv": np.arange(8.0)})
    fr = weldrel.Table({"k": np.arange(8).astype(np.float64),
                        "rv": np.arange(8.0)})
    with pytest.raises(NotImplementedError, match="anti"):
        weldrel.Query(left).join(mn, on="k", how="anti", precount=False)
    with pytest.raises(ValueError, match="m:1"):
        weldrel.Query(left).join(mn, on="k", validate="m:1",
                                 precount=False)
    with pytest.raises(ValueError, match="integer key"):
        weldrel.Query(fleft).join(fr, on="k", precount=False)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_rejects_before_any_tracing(join_tables):
    left, mn = join_tables
    obs.enable()
    obs.clear()
    pos = obs.mark()
    try:
        with pytest.raises(ResourceError, match="admission"):
            weldrel.Query(left).join(mn, on="k", precount=False,
                                     memory_limit=64)
        names = {s.name for s in obs.spans_since(pos)}
    finally:
        obs.disable()
        obs.clear()
    assert "bounds" in names
    # nothing was traced, compiled, or launched
    assert "jit_compile" not in names
    assert "execute" not in names
    assert not any(n.startswith("kernel.") or n.startswith("launch.")
                   for n in names)


def test_admission_admits_with_room(join_tables):
    left, mn = join_tables
    out = weldrel.Query(left).join(mn, on="k", precount=False,
                                   memory_limit=1 << 30)
    assert _mat(out, "k").size > 0


def test_bounds_disabled_skips_admission(join_tables):
    from repro.core import runtime

    left, mn = join_tables
    runtime.clear_cache()  # a cached hit would replay bounds.* stats
    bounds.set_enabled(False)
    try:
        st = {}
        weldrel.Query(left).join(mn, on="k", collect_stats=st)
        assert "bounds.certificate" not in st
    finally:
        bounds.set_enabled(None)


# ---------------------------------------------------------------------------
# recovery clamp
# ---------------------------------------------------------------------------


def _cap_loop(cap):
    bty = wt.DictMerger(wt.I64, wt.F64, "+")
    b, i, e = (ir.Ident("b", bty), ir.Ident("i", wt.I64),
               ir.Ident("e", wt.F64))
    return ir.Result(ir.For(
        (ir.Iter(XS),),
        ir.NewBuilder(bty, arg=ir.Literal(cap, wt.I64)),
        ir.Lambda((b, i, e),
                  ir.Merge(b, ir.MakeStruct((ir.Cast(e, wt.I64), e))))))


def _the_nb(prog):
    return next(n for n in ir.walk(prog)
                if isinstance(n, ir.NewBuilder))


def test_regrow_clamps_at_proven_upper_bound():
    prog = _cap_loop(2)
    nb = _the_nb(prog)
    grown, n = recovery.regrow_capacities(prog, 8,
                                          bounds={id(nb): (1, 4)})
    assert n == 1
    assert _the_nb(grown).arg.value == 4  # 2*8=16 clamped to ub=4


def test_regrow_skips_capacity_already_at_bound():
    prog = _cap_loop(8)
    nb = _the_nb(prog)
    grown, n = recovery.regrow_capacities(prog, 2,
                                          bounds={id(nb): (1, 4)})
    assert n == 0  # 8 >= ub 4: provably cannot overflow, unstamped


def test_regrow_jumps_to_proven_lower_bound():
    prog = _cap_loop(1)
    nb = _the_nb(prog)
    grown, n = recovery.regrow_capacities(prog, 2,
                                          bounds={id(nb): (100, 1000)})
    assert n == 1
    assert _the_nb(grown).arg.value == 100  # 1*2=2 jumps to lb


def test_regrow_without_bounds_unchanged():
    prog = _cap_loop(4)
    grown, n = recovery.regrow_capacities(prog, 2)
    assert n == 1 and _the_nb(grown).arg.value == 8
