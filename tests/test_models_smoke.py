"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned architecture and run one forward/train step + prefill/decode on
CPU, asserting output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import build_model

pytestmark = pytest.mark.slow  # one jit per architecture: ~1 min total

ARCHS = [a for a in list_configs() if a != "weld-bench"]

B, T = 2, 32


def _batch(model, rng):
    cfg = model.cfg
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, T)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, T)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.n_frames, cfg.d_model), cfg.act_dtype)
    if cfg.family == "vlm":
        batch["images"] = jnp.asarray(
            rng.randn(B, cfg.n_image_tokens, cfg.d_vision), cfg.act_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    rng = np.random.RandomState(0)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model, rng)
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grads not finite"
    assert float(gnorm) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    rng = np.random.RandomState(1)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(model, rng)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill NaN"

    # one decode step on a fresh full-size cache (decode-shape path)
    dcache = model.cache_init(B, T)
    tok = jnp.asarray(rng.randint(0, cfg.vocab, (B, 1)), jnp.int32)
    dec = jax.jit(model.decode_step)
    logits2, new_cache = dec(params, dcache, tok, jnp.int32(0))
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all(), f"{arch}: decode NaN"
    # cache must actually change
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(dcache),
                        jax.tree_util.tree_leaves(new_cache))
    )
    assert changed, f"{arch}: decode did not update cache"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_cover_params(arch):
    """Every parameter leaf has a logical-axes spec of matching rank."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = model.param_specs()
    flat_p = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_s = {jax.tree_util.keystr(k): v for k, v in
              jax.tree_util.tree_flatten_with_path(
                  specs, is_leaf=lambda x: isinstance(x, tuple))[0]}
    for path, leaf in flat_p:
        key = jax.tree_util.keystr(path)
        assert key in flat_s, f"{arch}: no spec for {key}"
        assert len(flat_s[key]) == len(leaf.shape), (
            f"{arch}: spec rank mismatch at {key}: "
            f"{flat_s[key]} vs {leaf.shape}"
        )


def test_decode_matches_prefill_dense():
    """Decode step-by-step must reproduce teacher-forced logits (dense)."""
    cfg = get_config("llama3.2-3b", smoke=True)
    model = build_model(cfg)
    rng = np.random.RandomState(2)
    params = model.init(jax.random.PRNGKey(2))
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 8)), jnp.int32)

    # teacher-forced full forward via prefill on growing prefixes
    logits_full, _ = model.prefill(params, {"tokens": toks})

    cache = model.cache_init(1, 8)
    dec = jax.jit(model.decode_step)
    logits_step = None
    for t in range(8):
        logits_step, cache = dec(params, cache, toks[:, t: t + 1],
                                 jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0]), np.asarray(logits_full[:, 0]),
        rtol=2e-3, atol=2e-3,
    )


def test_decode_matches_prefill_hybrid():
    """Same consistency for the zamba2 recurrent path."""
    cfg = get_config("zamba2-1.2b", smoke=True)
    model = build_model(cfg)
    rng = np.random.RandomState(3)
    params = model.init(jax.random.PRNGKey(3))
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 16)), jnp.int32)
    logits_full, _ = model.prefill(params, {"tokens": toks})
    cache = model.cache_init(1, 16)
    dec = jax.jit(model.decode_step)
    for t in range(16):
        logits_step, cache = dec(params, cache, toks[:, t: t + 1],
                                 jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0]), np.asarray(logits_full[:, 0]),
        rtol=5e-3, atol=5e-3,
    )


def test_decode_matches_prefill_xlstm():
    cfg = get_config("xlstm-350m", smoke=True)
    model = build_model(cfg)
    rng = np.random.RandomState(4)
    params = model.init(jax.random.PRNGKey(4))
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 16)), jnp.int32)
    logits_full, _ = model.prefill(params, {"tokens": toks})
    cache = model.cache_init(1, 16)
    dec = jax.jit(model.decode_step)
    for t in range(16):
        logits_step, cache = dec(params, cache, toks[:, t: t + 1],
                                 jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0]), np.asarray(logits_full[:, 0]),
        rtol=5e-3, atol=5e-3,
    )
