"""Differential join fuzzer: random tables x join specs against a
pandas oracle on every execution path.

Each generated case draws a probe and a build table (mixed dtypes,
duplicate keys with skewed fan-out, empty sides, float keys exact in
f32), a join spec (``how`` x single/multi-key x optional filter), and
asserts ROW-SET parity across eager / ``kernelize="off"`` / ``"auto"``
/ ``"always"`` — four implementations, one oracle.  Error parity is
fuzzed too: specs every path must reject (m:n anti) must raise on
every path.

Generation is seed-driven so the same machinery serves three profiles:

* a bounded, fixed-seed CI profile (``test_join_fuzz_quick``) that
  always runs;
* a >=200-example deep profile (``test_join_fuzz_deep``, marked slow —
  the "locally"/--full tier);
* a hypothesis property over the seed space, reusing the
  optional-import pattern from tests/test_kernels.py (runs only where
  hypothesis is installed, ``derandomize`` keeps CI deterministic).

Case sizes come from a small palette on purpose: the compile cache is
keyed on (structure, shapes), so repeated shape buckets amortize
compilation and the fuzzer spends its time EXECUTING joins.
"""
import numpy as np
import pytest
import jax

# the IR runtime enables x64 globally on import; do the same here so
# packed i64 keys survive when this module runs first/alone.
jax.config.update("jax_enable_x64", True)

try:  # pragma: no cover - environment-dependent
    import pandas as pd
except ImportError:
    pd = None

try:  # hypothesis is an optional extra (same pattern as test_kernels)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

    def given(**kw):  # no-op decorator: the test below is skipped
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(**kw):
        def deco(fn):
            return fn
        return deco

    class st:  # noqa: N801 - mirrors the hypothesis strategies namespace
        @staticmethod
        def integers(*a, **kw):
            return None

from repro.frames import weldrel  # noqa: E402

pytestmark = pytest.mark.skipif(pd is None, reason="pandas not installed")

MODES = ("eager", "off", "auto", "always")

#: shape palette (see module docstring: small on purpose, cache-friendly)
L_SIZES = (0, 1, 3, 17, 60)
R_SIZES = (0, 1, 4, 25)
VAL_KINDS = ("f64", "i64", "bool")


def make_case(rng: np.random.RandomState):
    """One random (lcols, rcols, on, how, filtered) join case."""
    nk = 1 if rng.rand() < 0.7 else 2
    how = ("inner", "left", "anti")[rng.randint(0, 3)]
    n_l = int(L_SIZES[rng.randint(0, len(L_SIZES))])
    n_r = int(R_SIZES[rng.randint(0, len(R_SIZES))])
    uni = int(rng.randint(1, 8))  # small key universe -> many duplicates
    float_keys = nk == 1 and rng.rand() < 0.25

    def keycol(n):
        c = rng.randint(0, uni, n)
        if n and rng.rand() < 0.3:  # skewed fan-out: one hot key
            c[rng.randint(0, n, max(n // 2, 1))] = int(rng.randint(0, uni))
        if float_keys:
            return c.astype(np.float64) * 0.5  # exact in f32: no conflation
        return c.astype(np.int64)

    lcols = {"k": keycol(n_l)}
    rcols = {"k": keycol(n_r)}
    if nk > 1:
        lcols["k2"] = rng.randint(0, 3, n_l).astype(np.int64)
        rcols["k2"] = rng.randint(0, 3, n_r).astype(np.int64)
    lcols["lv"] = rng.rand(n_l)
    kind = VAL_KINDS[rng.randint(0, len(VAL_KINDS))]
    if kind == "bool":
        rcols["rv"] = rng.rand(n_r) > 0.5
    elif kind == "i64":
        rcols["rv"] = rng.randint(-5, 5, n_r).astype(np.int64)
    else:
        rcols["rv"] = rng.rand(n_r)
    on = ["k", "k2"] if nk > 1 else "k"
    filtered = rng.rand() < 0.4
    return lcols, rcols, on, how, filtered


def pd_oracle(lcols, rcols, on, how, m=None, suffix="_r"):
    """pandas oracle for weldrel's join semantics (sentinel fills, not
    pandas' float upcast; anti via the merge indicator)."""
    on = [on] if isinstance(on, str) else list(on)
    ldf = pd.DataFrame(lcols)
    if m is not None:
        ldf = ldf[m]
    rdf = pd.DataFrame(rcols)
    if how == "anti":
        mg = ldf.merge(rdf[on].drop_duplicates(), on=on, how="left",
                       indicator=True)
        out = mg[mg["_merge"] == "left_only"]
        return {c: out[c].to_numpy() for c in ldf.columns}
    mg = ldf.merge(rdf, on=on, how=how, suffixes=("", suffix))
    out = {c: mg[c].to_numpy() for c in ldf.columns}
    for c in rdf.columns:
        if c in on:
            continue
        name = c + suffix if c in ldf.columns else c
        v = mg[name].to_numpy()
        want_dt = np.asarray(rcols[c]).dtype
        if how == "left" and not np.issubdtype(want_dt, np.floating):
            miss = np.isnan(v.astype(np.float64))
            v = np.where(miss, np.zeros((), want_dt), v).astype(want_dt)
        out[name] = v
    return out


def _rowset(d):
    cols = sorted(d)
    if not cols:
        return []
    rows = zip(*[np.asarray(d[c]).tolist() for c in cols])
    return sorted(tuple(repr(x) for x in r) for r in rows)


def _run(lcols, rcols, on, how, mode, filtered):
    eager = mode == "eager"
    t = weldrel.Table(lcols, eager=eager)
    r = weldrel.Table(rcols, eager=eager)
    q = weldrel.Query(t)
    if filtered:
        q = q.filter(t.col("lv") > 0.5)
    kw = {} if eager else {"kernelize": mode}
    out = q.join(r, on=on, how=how, **kw)
    return {c: np.asarray(weldrel._host(out.cols[c])) for c in out.cols}


def check_case(lcols, rcols, on, how, filtered):
    m = (lcols["lv"] > 0.5) if filtered else None
    dup = (pd.DataFrame(rcols)[[c for c in
                                (on if isinstance(on, list) else [on])]]
           .duplicated().any())
    if how == "anti" and dup:
        # error parity: m:n anti is rejected on EVERY path
        for mode in MODES:
            with pytest.raises(NotImplementedError):
                _run(lcols, rcols, on, how, mode, filtered)
        return
    want = _rowset(pd_oracle(lcols, rcols, on, how, m=m))
    for mode in MODES:
        got = _rowset(_run(lcols, rcols, on, how, mode, filtered))
        assert got == want, (
            f"join differs from pandas oracle: mode={mode} how={how} "
            f"on={on} filtered={filtered} n_l={len(lcols['k'])} "
            f"n_r={len(rcols['k'])}\n got[:5]={got[:5]}\nwant[:5]={want[:5]}"
        )


def _fuzz(n_examples: int, seed: int):
    rng = np.random.RandomState(seed)
    for _ in range(n_examples):
        check_case(*make_case(rng))


def test_join_fuzz_quick():
    """Bounded fixed-seed profile: always runs (CI gate)."""
    _fuzz(25, seed=2026)


@pytest.mark.slow
def test_join_fuzz_deep():
    """>=200 examples — the local / --full profile of the fuzzer."""
    # start from a clean compile state: after a full test_join.py run the
    # accumulated in-process XLA state can segfault the CPU backend's
    # compiler partway through this profile (reproducible at the seed
    # commit, independent of any repro-side code)
    import jax

    from repro.core import runtime

    runtime.clear_cache()
    jax.clear_caches()
    _fuzz(200, seed=515000)


def test_join_fuzz_verified():
    """WELD_VERIFY=1 profile: the whole generated corpus must verify
    clean (no false positives from weldcheck) on all four paths.  The
    compile cache is cleared first so every case actually re-verifies
    instead of hitting executables compiled before the override."""
    from repro.core import check, runtime

    runtime.clear_cache()
    check.set_enabled(True)
    try:
        _fuzz(10, seed=77)
    finally:
        check.set_enabled(None)


def test_join_fuzz_bounds_soundness():
    """weldbound soundness profile: for every generated lazy case the
    derived interval must contain the observed output size (stats AND
    an independent re-analysis of the planned IR), and a statically
    admitted plan must never trip the runtime memory limit — re-running
    with ``memory_limit`` set to exactly the certificate's peak must
    succeed, because the certificate mirrors the emitter's trace-time
    charges term for term."""
    from repro.core import runtime
    from repro.core.analysis import bounds

    rng = np.random.RandomState(424)
    checked = admitted_checked = 0
    for _ in range(15):
        lcols, rcols, on, how, filtered = make_case(rng)
        on_list = on if isinstance(on, list) else [on]
        if how == "anti" \
                and pd.DataFrame(rcols)[on_list].duplicated().any():
            continue  # error-parity shape: covered elsewhere

        def run(memory_limit=None):
            t = weldrel.Table(lcols, eager=False)
            r = weldrel.Table(rcols, eager=False)
            q = weldrel.Query(t)
            if filtered:
                q = q.filter(t.col("lv") > 0.5)
            st = {}
            out = q.join(r, on=on, how=how, memory_limit=memory_limit,
                         collect_stats=st)
            n = np.asarray(weldrel._host(out.cols["k"])).size
            return n, st

        observed, st = run()
        checked += 1
        assert st["bounds.out_rows"] is not None, (how, filtered)
        lo, hi = st["bounds.out_rows"]
        assert lo <= observed, (how, filtered, lo, observed)
        assert hi is None or observed <= hi, (how, filtered, observed, hi)
        # independent re-derivation from the planned IR agrees
        rep = bounds.analyze(st["plan.ir"])
        lo2, hi2 = rep.result_rows(st["plan.inputs"][2])
        assert lo2 <= observed, (how, filtered, lo2, observed)
        assert hi2 is None or observed <= hi2
        # admission exactness: limit == certificate peak must admit AND
        # survive the emitter's own trace-time charging
        peak = st["bounds.peak_bytes"]
        if peak > 0:
            runtime.clear_cache()
            observed2, st2 = run(memory_limit=peak)
            assert observed2 == observed
            assert st2["bounds.admitted"] is True
            admitted_checked += 1
    assert checked >= 8  # the seed must exercise a real corpus
    assert admitted_checked >= 1


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=40, deadline=None, derandomize=True)
def test_join_fuzz_hypothesis(seed):
    """Property form over the generator's seed space (shrinks to the
    smallest failing seed); bounded + derandomized for CI."""
    check_case(*make_case(np.random.RandomState(seed)))


# ---------------------------------------------------------------------------
# fault-injection profile: random failpoints, same oracle
# ---------------------------------------------------------------------------


def check_case_faulted(rng, lcols, rcols, on, how, filtered):
    """Re-run a generated case with a randomly drawn failpoint armed and
    assert the recovered result still matches the pandas oracle; with
    recovery disabled the same fault surfaces as a typed error."""
    import warnings

    from repro.core import faults, recovery, runtime
    from repro.core.errors import CapacityError

    m = (lcols["lv"] > 0.5) if filtered else None
    on_list = on if isinstance(on, list) else [on]
    if how == "anti" and pd.DataFrame(rcols)[on_list].duplicated().any():
        return  # error-parity shape: covered by the healthy profile
    want = _rowset(pd_oracle(lcols, rcols, on, how, m=m))
    # capacity faults only bite when something gets built (n_r > 0);
    # kernel faults only bite when a kernel routes — both are fine to
    # arm unconditionally (an unfired fault must be a no-op)
    site, action, value = (
        ("join.capacity", "cap", 1),
        ("kernel.group_build", "raise", None),
        ("kernel.hash_build", "raise", None),
        ("decode", "poison", None),
    )[rng.randint(0, 4)]
    mode = ("off", "always")[rng.randint(0, 2)]
    try:
        faults.inject(site, action, times=1, value=value)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            got = _rowset(_run(lcols, rcols, on, how, mode, filtered))
        assert got == want, (
            f"faulted join differs from pandas oracle: fault={site}:{action} "
            f"mode={mode} how={how} on={on} filtered={filtered}\n"
            f" got[:5]={got[:5]}\nwant[:5]={want[:5]}"
        )
        fired = [f["site"] for f in faults.fired()]
        if site == "decode" and site in fired:
            # a consumed decode poison MUST have gone through the ladder:
            # recovery disabled turns the very same case into a typed error
            faults.clear()
            runtime.clear_cache()
            faults.inject(site, action, times=1, value=value)
            with recovery.disabled():
                with pytest.raises(CapacityError):
                    _run(lcols, rcols, on, how, mode, filtered)
    finally:
        faults.clear()


def test_join_fuzz_fault_injection(tmp_path, monkeypatch):
    """Seeded fault-injection profile: every case recovers to oracle
    parity (or the fault provably never fired)."""
    from repro.core.kernelplan import quarantine

    # kernel-raise faults quarantine their target — keep that out of
    # the developer's real health file and out of later tests
    monkeypatch.setenv(quarantine.ENV_FILE,
                       str(tmp_path / "kernel_health.json"))
    quarantine.clear(disk=False)
    try:
        rng = np.random.RandomState(77)
        for _ in range(12):
            case = make_case(rng)
            check_case_faulted(rng, *case)
    finally:
        quarantine.clear(disk=False)
