"""Hash-join correctness and routing: weldrel.Query.join against a NumPy
oracle on the eager, lazy-generic, and kernelized paths; kernel-level
ref/interpret parity for the open-addressing build and the one-hot
probe; planner routing decisions (probed dicts take the hash route, the
dense group-by route is untouched, the cost gate rejects tiny inputs)."""
import numpy as np
import pytest

from repro.frames import weldrel

rng = np.random.RandomState(13)


def np_join(lcols, rcols, on, m=None):
    """m:1 inner-join oracle; right keys must be unique."""
    lk, rk = lcols[on], rcols[on]
    mask = np.ones(lk.shape[0], bool) if m is None else m
    order = np.argsort(rk, kind="stable")
    rks = rk[order]
    if rks.size:
        pos = np.clip(np.searchsorted(rks, lk), 0, rks.size - 1)
        found = rks[pos] == lk
    else:
        found = np.zeros(lk.shape[0], bool)
    sel = mask & found
    out = {c: v[sel] for c, v in lcols.items()}
    if rks.size:
        gidx = order[pos[sel]]
        for c, v in rcols.items():
            if c != on:
                out[c] = v[gidx]
    else:
        for c, v in rcols.items():
            if c != on:
                out[c] = v[:0]
    return out


def _got(table):
    return {c: np.asarray(weldrel._host(table.cols[c])) for c in table.cols}


def _check(table, want):
    got = _got(table)
    assert set(got) == set(want)
    for c in want:
        np.testing.assert_allclose(got[c], want[c], rtol=1e-12)


def _data(n=1500, k=64, key_lo=0, key_hi=100, scale=1):
    lcols = {"key": (rng.randint(key_lo, key_hi, n) * scale).astype(np.int64),
             "lv": rng.rand(n)}
    rcols = {"key": (np.arange(k) * scale).astype(np.int64),
             "rv": rng.rand(k),
             "rw": rng.randint(0, 9, k).astype(np.int64)}
    return lcols, rcols


# ---------------------------------------------------------------------------
# oracle parity on all three execution paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["eager", "off", "always", "auto"])
def test_join_matches_numpy_oracle(mode):
    lcols, rcols = _data()
    want = np_join(lcols, rcols, "key")
    if mode == "eager":
        t = weldrel.Table(lcols, eager=True)
        r = weldrel.Table(rcols, eager=True)
        out = weldrel.Query(t).join(r, on="key")
    else:
        t = weldrel.Table(lcols, eager=False)
        r = weldrel.Table(rcols, eager=False)
        out = weldrel.Query(t).join(r, on="key", kernelize=mode)
    _check(out, want)


def test_join_kernelized_routes_and_matches():
    lcols, rcols = _data()
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    st: dict = {}
    out = weldrel.Query(t).join(r, on="key", kernelize="always",
                                collect_stats=st)
    assert st["kernelize.dict_hash_build"] == 1
    # 4 output columns (key, lv, rv, rw) share ONE fused probe launch
    assert st["kernelize.hash_probe"] == 1
    assert st["kernelplan"]["routed"]["hash_probe"] == 1
    _check(out, np_join(lcols, rcols, "key"))


def test_join_with_filter_predicate():
    lcols, rcols = _data()
    for eager in (False, True):
        t = weldrel.Table(lcols, eager=eager)
        r = weldrel.Table(rcols, eager=eager)
        q = weldrel.Query(t).filter(t.col("lv") > 0.5)
        kw = {} if eager else {"kernelize": "always"}
        out = q.join(r, on="key", **kw)
        _check(out, np_join(lcols, rcols, "key", m=lcols["lv"] > 0.5))


def test_join_sparse_keys_kernelized():
    """Keys far outside any dense [0, capacity) range: the dense group-by
    route would poison these — the hash route must handle them."""
    lcols, rcols = _data(scale=1_000_003)
    lcols["key"] -= 5  # include negative-ish offsets of the lattice
    rcols["key"] -= 5
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    st: dict = {}
    out = weldrel.Query(t).join(r, on="key", kernelize="always",
                                collect_stats=st)
    assert st["kernelize.dict_hash_build"] == 1
    _check(out, np_join(lcols, rcols, "key"))


def test_join_duplicate_probe_keys_and_misses():
    lcols = {"key": np.array([3, 3, 3, 99, 5, 3], np.int64),
             "lv": np.arange(6.0)}
    rcols = {"key": np.array([5, 3], np.int64), "rv": np.array([0.5, 0.25])}
    want = np_join(lcols, rcols, "key")
    for mode in ("eager", "off", "always"):
        if mode == "eager":
            out = weldrel.Query(weldrel.Table(lcols, eager=True)).join(
                weldrel.Table(rcols, eager=True), on="key")
        else:
            out = weldrel.Query(weldrel.Table(lcols, eager=False)).join(
                weldrel.Table(rcols, eager=False), on="key", kernelize=mode)
        _check(out, want)


@pytest.mark.parametrize("which", ["left", "right", "both"])
def test_join_empty_sides(which):
    lcols, rcols = _data(n=200, k=16)
    if which in ("left", "both"):
        lcols = {c: v[:0] for c, v in lcols.items()}
    if which in ("right", "both"):
        rcols = {c: v[:0] for c, v in rcols.items()}
    want = np_join(lcols, rcols, "key")
    for mode in ("eager", "off", "always"):
        if mode == "eager":
            out = weldrel.Query(weldrel.Table(lcols, eager=True)).join(
                weldrel.Table(rcols, eager=True), on="key")
        else:
            out = weldrel.Query(weldrel.Table(lcols, eager=False)).join(
                weldrel.Table(rcols, eager=False), on="key", kernelize=mode)
        got = _got(out)
        assert all(got[c].size == 0 for c in got)
        assert set(got) == set(want)


@pytest.mark.parametrize("eager", [True, False])
def test_join_validate_m1_rejects_duplicate_build_keys(eager):
    """Duplicate build keys are legal by default now (m:n); the pandas
    ``validate="m:1"`` knob restores the old rejection, with a
    row-count diagnostic."""
    lcols = {"key": np.array([1, 7], np.int64)}
    rcols = {"key": np.array([7, 7], np.int64), "rv": np.array([1.0, 2.0])}
    t = weldrel.Table(lcols, eager=eager)
    r = weldrel.Table(rcols, eager=eager)
    with pytest.raises(ValueError,
                       match=r"m:1.*1 duplicate key rows.*1 distinct"):
        weldrel.Query(t).join(r, on="key", validate="m:1")
    with pytest.raises(ValueError, match="validate"):
        weldrel.Query(weldrel.Table(lcols, eager=eager)).join(
            weldrel.Table(rcols, eager=eager), on="key", validate="1:1")
    # default: key 7 fans out to both build rows
    out = weldrel.Query(weldrel.Table(lcols, eager=eager)).join(
        weldrel.Table(rcols, eager=eager), on="key")
    got = _got(out)
    np.testing.assert_array_equal(got["key"], [7, 7])
    np.testing.assert_allclose(got["rv"], [1.0, 2.0])


def test_join_suffix_and_right_on():
    lcols = {"k": np.array([1, 2, 3], np.int64), "v": np.arange(3.0)}
    rcols = {"rk": np.array([2, 3], np.int64), "v": np.array([9.0, 8.0])}
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    out = weldrel.Query(t).join(r, on="k", right_on="rk", kernelize="off")
    got = _got(out)
    assert set(got) == {"k", "v", "v_r"}
    np.testing.assert_array_equal(got["k"], [2, 3])
    np.testing.assert_allclose(got["v_r"], [9.0, 8.0])


def test_join_interpret_impl_parity():
    lcols, rcols = _data(n=300, k=16)
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    a = weldrel.Query(t).join(r, on="key", kernelize="always",
                              kernel_impl="ref")
    b = weldrel.Query(t).join(r, on="key", kernelize="always",
                              kernel_impl="interpret")
    for c in a.cols:
        np.testing.assert_allclose(_got(a)[c], _got(b)[c], rtol=1e-12)


def test_join_rejects_unsupported_shapes():
    t = weldrel.Table({"k": np.array([1], np.int64)})
    r = weldrel.Table({"k": np.array([1], np.int64)})
    with pytest.raises(NotImplementedError):
        weldrel.Query(t).join(r, on="k", how="outer")
    with pytest.raises(TypeError):
        weldrel.Query(t).join(weldrel.Query(r), on="k")
    with pytest.raises(ValueError, match="at most 2"):
        weldrel.Query(weldrel.Table({
            "a": np.array([1], np.int64), "b": np.array([1], np.int64),
            "c": np.array([1], np.int64)})).join(
            weldrel.Table({"a": np.array([1], np.int64),
                           "b": np.array([1], np.int64),
                           "c": np.array([1], np.int64)}),
            on=["a", "b", "c"])


def test_join_keys_beyond_32_bits_do_not_conflate():
    """Single int key columns pack full-width: keys that agree in the
    low 32 bits (e.g. 1 vs 2^32+1) must not be conflated on any path."""
    lcols = {"key": np.array([1, 2 ** 32 + 1, 5], np.int64),
             "lv": np.arange(3.0)}
    rcols = {"key": np.array([2 ** 32 + 1], np.int64),
             "rv": np.array([7.0])}
    want = np_join(lcols, rcols, "key")
    assert want["key"].tolist() == [2 ** 32 + 1]
    for mode in ("eager", "off", "always"):
        if mode == "eager":
            out = weldrel.Query(weldrel.Table(lcols, eager=True)).join(
                weldrel.Table(rcols, eager=True), on="key")
        else:
            out = weldrel.Query(weldrel.Table(lcols, eager=False)).join(
                weldrel.Table(rcols, eager=False), on="key", kernelize=mode)
        _check(out, want)


@pytest.mark.parametrize("eager", [True, False])
def test_join_undersized_capacity_raises(eager):
    lcols = {"key": np.arange(10, dtype=np.int64)}
    rcols = {"key": np.arange(8, dtype=np.int64), "rv": rng.rand(8)}
    t = weldrel.Table(lcols, eager=eager)
    r = weldrel.Table(rcols, eager=eager)
    with pytest.raises(ValueError, match="capacity"):
        weldrel.Query(t).join(r, on="key", capacity=4)


# ---------------------------------------------------------------------------
# routing decisions
# ---------------------------------------------------------------------------


def test_probe_not_routed_beyond_vmem_capacity():
    """A build side beyond the hash kernels' capacity bound must keep
    BOTH sides on the generic lowering under kernelize='always' — the
    probe's one-hot tile cannot exceed its VMEM budget either."""
    from repro.kernels.hash_table import MAX_CAP

    k = MAX_CAP + 512
    n = 4096
    lcols = {"key": rng.randint(0, k, n).astype(np.int64), "lv": rng.rand(n)}
    rcols = {"key": np.arange(k, dtype=np.int64), "rv": rng.rand(k)}
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    st: dict = {}
    out = weldrel.Query(t).join(r, on="key", kernelize="always",
                                collect_stats=st)
    assert st.get("kernelize.dict_hash_build", 0) == 0, st.get("kernelplan")
    assert st.get("kernelize.hash_probe", 0) == 0, st.get("kernelplan")
    _check(out, np_join(lcols, rcols, "key"))


def test_join_auto_routes_large_and_rejects_tiny():
    n, k = 300_000, 20_000
    lcols = {"key": rng.randint(0, 2 * k, n).astype(np.int64),
             "lv": rng.rand(n)}
    rcols = {"key": np.arange(k, dtype=np.int64), "rv": rng.rand(k)}
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    st: dict = {}
    out = weldrel.Query(t).join(r, on="key", kernelize="auto",
                                collect_stats=st)
    assert st.get("kernelize.dict_hash_build", 0) == 1, st.get("kernelplan")
    assert st.get("kernelize.hash_probe", 0) >= 1, st.get("kernelplan")
    _check(out, np_join(lcols, rcols, "key"))
    # tiny inputs: padding + launch overhead dominate -> gate keeps jnp
    lcols2, rcols2 = _data(n=100, k=8)
    st2: dict = {}
    out2 = weldrel.Query(weldrel.Table(lcols2, eager=False)).join(
        weldrel.Table(rcols2, eager=False), on="key", kernelize="auto",
        collect_stats=st2)
    assert st2["kernelize.matched"] == 0, st2.get("kernelplan")
    assert st2["kernelplan"]["rejected"].get("hash_probe", 0) >= 1
    _check(out2, np_join(lcols2, rcols2, "key"))


def test_groupby_hash_route_beyond_dense_capacity():
    """Capacities beyond the dense segment tile (4096) used to fall back
    to the generic sort path; the hash route now serves them."""
    from repro.frames import welddf

    n = 50_000
    key = rng.randint(0, 20_000, n).astype(np.int64)
    val = rng.rand(n)
    df = welddf.DataFrame({"k": key, "v": val})
    st: dict = {}
    d1 = df.groupby_sum("k", "v", capacity=32768, kernelize=True,
                        collect_stats=st)
    assert st["kernelize.dict_hash_build"] == 1
    d0 = df.groupby_sum("k", "v", capacity=32768, kernelize=False)
    assert set(d1) == set(d0)
    for kk in d0:
        np.testing.assert_allclose(d1[kk], d0[kk], rtol=1e-10)


def test_dense_groupby_route_unchanged():
    """Probing is what selects the hash build; a plain in-range group-by
    must still take the dense segment route."""
    from repro.frames import welddf

    key = rng.randint(0, 50, 4096).astype(np.int64)
    val = rng.rand(4096)
    df = welddf.DataFrame({"k": key, "v": val})
    st: dict = {}
    df.groupby_sum("k", "v", capacity=64, kernelize=True, collect_stats=st)
    assert st.get("kernelize.dict_group_sum", 0) == 1
    assert st.get("kernelize.dict_hash_build", 0) == 0


def test_hash_build_sparse_keys_decode_correctly():
    """Sparse keys through the hash route: capacity 4097 skips the dense
    route (tile bound 4096) and lands on dict_hash_build; the decoded
    dict must agree with the generic lowering."""
    from repro.core import ir, macros as M
    from repro.core.lazy import Evaluate, NewWeldObject

    keys = NewWeldObject(np.arange(100, dtype=np.int64) * 11, None)
    vals = NewWeldObject(rng.rand(100), None)
    kid = ir.Ident(keys.obj_id, keys.weld_type())
    vid = ir.Ident(vals.obj_id, vals.weld_type())
    d = M.groupby_agg(kid, vid, "+", capacity=4097)
    obj = NewWeldObject([keys, vals], d)
    st: dict = {}
    out = Evaluate(obj, kernelize="always", collect_stats=st)
    assert st.get("kernelize.dict_hash_build", 0) == 1
    assert len(out.value) == 100
    want = Evaluate(obj, kernelize=False).value
    assert set(out.value) == set(want)
    for kk in want:
        np.testing.assert_allclose(out.value[kk], want[kk], rtol=1e-10)


def test_hash_build_overflow_recovers_by_regrowing():
    """An undersized hash build poisons the dict; the recovery runtime
    re-stamps the capacity and retries instead of surfacing the poison.
    With recovery disabled the typed CapacityError reaches the caller."""
    import warnings

    from repro.core import ir, macros as M, recovery
    from repro.core.errors import CapacityError
    from repro.core.lazy import Evaluate, NewWeldObject

    def mk():
        keys = NewWeldObject(np.arange(8000, dtype=np.int64) * 3, None)
        vals = NewWeldObject(rng.rand(8000), None)
        kid = ir.Ident(keys.obj_id, keys.weld_type())
        vid = ir.Ident(vals.obj_id, vals.weld_type())
        d = M.groupby_agg(kid, vid, "+", capacity=4097)  # 8000 > 4097
        return NewWeldObject([keys, vals], d)

    st: dict = {}
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = Evaluate(mk(), kernelize="always", collect_stats=st)
    assert st["recovery.attempts"] >= 2
    assert any("regrow" in e["action"] for e in st["recovery.events"])
    assert any("weld recovery" in str(x.message) for x in w)
    assert len(out.value) == 8000
    want = Evaluate(mk(), kernelize=False).value
    assert set(out.value) == set(want)
    with recovery.disabled():
        with pytest.raises(CapacityError):
            Evaluate(mk(), kernelize="always")


# ---------------------------------------------------------------------------
# kernel-level parity: ref oracle vs interpreted Pallas kernels
# ---------------------------------------------------------------------------


def test_hash_to_slot_contract_both_impls():
    from repro.kernels import ops as kops
    from repro.kernels.hash_table import EMPTY, table_size

    keys = np.concatenate([
        rng.randint(-50, 50, 300).astype(np.int64) * 999_983,
        np.array([EMPTY] * 5, np.int64),
    ])
    rng.shuffle(keys)
    C = table_size(128)
    for impl in ("ref", "interpret"):
        slots, table, used = map(np.asarray, kops.hash_to_slot(
            np.asarray(keys), C, impl=impl))
        valid = keys != EMPTY
        assert used == np.unique(keys[valid]).size
        assert (slots[~valid] == C).all()
        assert (table[slots[valid]] == keys[valid]).all()
        # distinct keys -> distinct slots
        uniq = {}
        for kk, s in zip(keys[valid], slots[valid]):
            assert uniq.setdefault(kk, s) == s
        assert len(set(uniq.values())) == len(uniq)


def test_dict_probe_parity_both_impls():
    from repro.kernels import ops as kops

    cap, count = 64, 40
    table = np.sort(rng.choice(10_000, count, replace=False)).astype(np.int64)
    table = np.concatenate([table, np.full(cap - count, 77_777, np.int64)])
    big = np.iinfo(np.int64).max
    neut = np.where(np.arange(cap) < count, table, big)
    queries = rng.randint(0, 10_000, 500).astype(np.int64)
    got = {}
    for impl in ("ref", "interpret"):
        pos, found = map(np.asarray, kops.dict_probe(
            neut, count, queries, impl=impl))
        got[impl] = (pos, found)
        want_found = np.isin(queries, table[:count])
        np.testing.assert_array_equal(found, want_found)
        np.testing.assert_array_equal(
            table[pos[found]], queries[found])
        assert (pos[~found] == 0).all()
    np.testing.assert_array_equal(got["ref"][0], got["interpret"][0])


# ---------------------------------------------------------------------------
# left / anti / multi-key joins: pandas-oracle parity on every path
# (pandas is a dev-only dependency — only the oracle tests skip without
# it, never this module's routing/correctness tests above)
# ---------------------------------------------------------------------------

try:
    import pandas as pd
except ImportError:  # pragma: no cover - dev envs ship pandas
    pd = None

needs_pandas = pytest.mark.skipif(pd is None, reason="pandas not installed")

MODES = ("eager", "off", "auto", "always")


def pd_join(lcols, rcols, on, how, m=None, suffix="_r"):
    """pandas oracle for weldrel's join semantics.  Left-join misses in
    non-float right columns are converted from pandas' NaN-upcast back
    to weldrel's per-dtype sentinel fills (0 / False)."""
    on = [on] if isinstance(on, str) else list(on)
    ldf = pd.DataFrame(lcols)
    if m is not None:
        ldf = ldf[m]
    rdf = pd.DataFrame(rcols)
    if how == "anti":
        mg = ldf.merge(rdf[on], on=on, how="left", indicator=True)
        out = mg[mg["_merge"] == "left_only"]
        return {c: out[c].to_numpy() for c in ldf.columns}
    mg = ldf.merge(rdf, on=on, how=how, suffixes=("", suffix))
    out = {c: mg[c].to_numpy() for c in ldf.columns}
    for c in rdf.columns:
        if c in on:
            continue
        name = c + suffix if c in ldf.columns else c
        v = mg[name].to_numpy()
        want_dt = np.asarray(rcols[c]).dtype
        if how == "left" and not np.issubdtype(want_dt, np.floating):
            miss = np.isnan(v.astype(np.float64))
            v = np.where(miss, np.zeros((), want_dt), v).astype(want_dt)
        out[name] = v
    return out


def _run_join(lcols, rcols, on, how, mode, pred_col=None, pred_thresh=None,
              collect_stats=None):
    eager = mode == "eager"
    t = weldrel.Table(lcols, eager=eager)
    r = weldrel.Table(rcols, eager=eager)
    q = weldrel.Query(t)
    if pred_col is not None:
        q = q.filter(t.col(pred_col) > pred_thresh)
    kw = {} if eager else {"kernelize": mode}
    return q.join(r, on=on, how=how, collect_stats=collect_stats, **kw)


@needs_pandas
@pytest.mark.parametrize("how", ["left", "anti"])
@pytest.mark.parametrize("mode", MODES)
def test_left_anti_join_pandas_parity(how, mode):
    lcols, rcols = _data()
    want = pd_join(lcols, rcols, "key", how)
    _check(_run_join(lcols, rcols, "key", how, mode), want)


@needs_pandas
@pytest.mark.parametrize("how", ["inner", "left", "anti"])
@pytest.mark.parametrize("mode", MODES)
def test_multi_key_join_pandas_parity(how, mode):
    n = 1200
    lcols = {"a": rng.randint(0, 12, n).astype(np.int64),
             "b": rng.randint(0, 7, n).astype(np.int64),
             "lv": rng.rand(n)}
    ga, gb = np.meshgrid(np.arange(10), np.arange(5))
    rcols = {"a": ga.ravel().astype(np.int64),
             "b": gb.ravel().astype(np.int64),
             "rv": rng.rand(50),
             "ri": rng.randint(0, 9, 50).astype(np.int64)}
    want = pd_join(lcols, rcols, ["a", "b"], how)
    _check(_run_join(lcols, rcols, ["a", "b"], how, mode), want)


@needs_pandas
@pytest.mark.parametrize("how", ["inner", "left", "anti"])
@pytest.mark.parametrize("mode", MODES)
def test_filtered_left_anti_multi_key_parity(how, mode):
    lcols = {"a": rng.randint(0, 9, 800).astype(np.int64),
             "b": rng.randint(0, 4, 800).astype(np.int64),
             "lv": rng.rand(800)}
    rcols = {"a": np.repeat(np.arange(7), 3).astype(np.int64),
             "b": np.tile(np.arange(3), 7).astype(np.int64),
             "rv": rng.rand(21)}
    want = pd_join(lcols, rcols, ["a", "b"], how, m=lcols["lv"] > 0.35)
    got = _run_join(lcols, rcols, ["a", "b"], how, mode,
                    pred_col="lv", pred_thresh=0.35)
    _check(got, want)


@pytest.mark.parametrize("how", ["left", "anti"])
@pytest.mark.parametrize("mode", MODES)
def test_all_miss_probe(how, mode):
    """Every probe key misses: left fills every right cell, anti keeps
    every row; dtypes must survive exactly."""
    lcols = {"key": (rng.randint(0, 50, 300) + 1000).astype(np.int64),
             "lv": rng.rand(300)}
    rcols = {"key": np.arange(20, dtype=np.int64), "rv": rng.rand(20),
             "ri": rng.randint(1, 9, 20).astype(np.int64)}
    got = _got(_run_join(lcols, rcols, "key", how, mode))
    np.testing.assert_array_equal(got["key"], lcols["key"])
    if how == "left":
        assert np.isnan(got["rv"]).all() and got["rv"].dtype == np.float64
        assert (got["ri"] == 0).all() and got["ri"].dtype == np.int64
    else:
        assert set(got) == {"key", "lv"}


@pytest.mark.parametrize("mode", MODES)
def test_left_join_fill_dtypes(mode):
    """Miss fills are per-dtype sentinels (NaN / 0 / False), never a
    silent float upcast — int and bool columns keep their dtype."""
    lcols = {"key": np.array([0, 1, 5, 7], np.int64)}
    rcols = {"key": np.array([1, 5], np.int64),
             "f": np.array([0.5, 0.25]),
             "i": np.array([3, 4], np.int64),
             "g": np.array([1.5, 2.5], np.float32)}
    got = _got(_run_join(lcols, rcols, "key", "left", mode))
    assert got["f"].dtype == np.float64 and np.isnan(got["f"][[0, 3]]).all()
    assert got["i"].dtype == np.int64
    np.testing.assert_array_equal(got["i"], [0, 3, 4, 0])
    assert got["g"].dtype == np.float32 and np.isnan(got["g"][[0, 3]]).all()
    np.testing.assert_allclose(got["g"][[1, 2]], [1.5, 2.5])


@needs_pandas
@pytest.mark.parametrize("how", ["left", "anti"])
@pytest.mark.parametrize("which", ["left", "right", "both"])
def test_left_anti_join_empty_sides(how, which):
    lcols, rcols = _data(n=150, k=12)
    if which in ("left", "both"):
        lcols = {c: v[:0] for c, v in lcols.items()}
    if which in ("right", "both"):
        rcols = {c: v[:0] for c, v in rcols.items()}
    want = pd_join(lcols, rcols, "key", how)
    for mode in MODES:
        got = _got(_run_join(lcols, rcols, "key", how, mode))
        assert set(got) == set(want)
        for c in want:
            np.testing.assert_allclose(
                got[c], np.asarray(want[c], got[c].dtype))


@needs_pandas
def test_left_anti_fused_single_probe_routing():
    """An N-output-column left/anti join must launch exactly one build
    and ONE fused probe under kernelize='always'."""
    lcols, rcols = _data()
    for how, ncols in (("left", 4), ("anti", 2)):
        st: dict = {}
        out = _run_join(lcols, rcols, "key", how, "always",
                        collect_stats=st)
        assert len(out.cols) == ncols
        assert st.get("kernelize.hash_probe", 0) == 1, st.get("kernelplan")
        if how == "left":
            assert st.get("kernelize.dict_hash_build", 0) == 1
        _check(out, pd_join(lcols, rcols, "key", how))


@pytest.mark.parametrize("how", ["inner", "left", "anti"])
def test_left_anti_multi_key_interpret_impl_parity(how):
    lcols = {"a": rng.randint(0, 8, 256).astype(np.int64),
             "b": rng.randint(0, 4, 256).astype(np.int64),
             "lv": rng.rand(256)}
    rcols = {"a": np.repeat(np.arange(6), 3).astype(np.int64),
             "b": np.tile(np.arange(3), 6).astype(np.int64),
             "rv": rng.rand(18)}
    outs = {}
    for impl in ("ref", "interpret"):
        t = weldrel.Table(lcols, eager=False)
        r = weldrel.Table(rcols, eager=False)
        outs[impl] = _got(weldrel.Query(t).join(
            r, on=["a", "b"], how=how, kernelize="always",
            kernel_impl=impl))
    for c in outs["ref"]:
        np.testing.assert_allclose(outs["ref"][c], outs["interpret"][c])


# ---------------------------------------------------------------------------
# pinned key semantics: NaN keys raise, name collisions raise,
# packed-space overflow raises — identically on every path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("side", ["probe", "build"])
def test_nan_join_keys_raise_everywhere(mode, side):
    lk = np.array([1.0, np.nan, 3.0]) if side == "probe" \
        else np.array([1.0, 2.0, 3.0])
    rk = np.array([1.0, np.nan]) if side == "build" \
        else np.array([1.0, 2.0])
    lcols = {"key": lk, "lv": np.arange(3.0)}
    rcols = {"key": rk, "rv": np.arange(float(rk.size))}
    with pytest.raises(ValueError, match="NaN"):
        _run_join(lcols, rcols, "key", "inner", mode)


@pytest.mark.parametrize("eager", [True, False])
def test_join_output_name_collision_raises(eager):
    """Left already has `v` and `v_r`; right's `v` would suffix onto the
    existing `v_r` — silently overwriting before this fix."""
    lcols = {"key": np.array([1, 2], np.int64),
             "v": np.arange(2.0), "v_r": np.arange(2.0)}
    rcols = {"key": np.array([1, 2], np.int64), "v": np.array([9.0, 8.0])}
    t = weldrel.Table(lcols, eager=eager)
    r = weldrel.Table(rcols, eager=eager)
    with pytest.raises(ValueError, match="collision"):
        weldrel.Query(t).join(r, on="key")
    # a different suffix resolves it
    out = weldrel.Query(t).join(r, on="key", suffix="_right")
    assert set(out.cols) == {"key", "v", "v_r", "v_right"}


@pytest.mark.parametrize("mode", MODES)
def test_float_join_keys_compare_at_f32_on_every_path(mode):
    """Float keys live in the packed key space's f32 bitcast on the
    dict paths; the eager compare and the uniqueness check now use the
    SAME packing, so build keys distinct only beyond f32 precision
    raise (the dictmerger would silently sum them) and identical
    payloads match identically everywhere."""
    lcols = {"key": np.array([0.5, 2.25, 7.0]), "lv": np.arange(3.0)}
    rcols = {"key": np.array([2.25, 0.5]), "rv": np.array([10.0, 20.0])}
    got = _got(_run_join(lcols, rcols, "key", "inner", mode))
    np.testing.assert_allclose(got["key"], [0.5, 2.25])
    np.testing.assert_allclose(got["rv"], [20.0, 10.0])
    # f32-colliding f64 build keys: conflated by the packed space, and
    # no longer caught by a uniqueness guard (m:n made duplicates
    # legal) — the explicit conflation check must reject them up front
    bad = {"key": np.array([1.0, 1.0 + 1e-12]), "rv": np.array([1.0, 2.0])}
    with pytest.raises(ValueError, match="conflate"):
        _run_join(lcols, bad, "key", "inner", mode)


@pytest.mark.parametrize("mode", MODES)
def test_mismatched_key_dtypes_raise_everywhere(mode):
    """An int key against a float key would silently bitcast-collide on
    the eager packed compare while the lazy dict raises a type error —
    pinned: every path raises the same ValueError up front."""
    lcols = {"key": np.array([1065353216], np.int64)}  # f32 bits of 1.0
    rcols = {"key": np.array([1.0]), "rv": np.array([99.0])}
    with pytest.raises(ValueError, match="dtype mismatch"):
        _run_join(lcols, rcols, "key", "inner", mode)


@pytest.mark.parametrize("how", ["inner", "left"])
@pytest.mark.parametrize("mode", MODES)
def test_bool_value_column_all_paths(how, mode):
    """Bool build-side value columns ride the dictmerger as i8 and cast
    back at the probe; left-join misses fill with False."""
    lcols = {"key": np.array([0, 1, 5, 2], np.int64)}
    rcols = {"key": np.array([1, 2, 3], np.int64),
             "flag": np.array([True, False, True])}
    got = _got(_run_join(lcols, rcols, "key", how, mode))
    assert got["flag"].dtype == np.bool_
    if how == "inner":
        np.testing.assert_array_equal(got["key"], [1, 2])
        np.testing.assert_array_equal(got["flag"], [True, False])
    else:
        np.testing.assert_array_equal(got["flag"],
                                      [False, True, False, False])


@pytest.mark.parametrize("eager", [True, False])
def test_multi_key_beyond_32_bits_raises(eager):
    lcols = {"a": np.array([2 ** 33, 1], np.int64),
             "b": np.array([0, 1], np.int64)}
    rcols = {"a": np.array([1], np.int64), "b": np.array([1], np.int64),
             "rv": np.array([1.0])}
    t = weldrel.Table(lcols, eager=eager)
    r = weldrel.Table(rcols, eager=eager)
    with pytest.raises(ValueError, match="32 bits"):
        weldrel.Query(t).join(r, on=["a", "b"])
    # INT32_MIN packs onto the hash EMPTY sentinel — reserved, raises
    l2 = {"a": np.array([-(2 ** 31), 1], np.int64),
          "b": np.array([0, 1], np.int64)}
    t2 = weldrel.Table(l2, eager=eager)
    with pytest.raises(ValueError, match="32 bits"):
        weldrel.Query(t2).join(r, on=["a", "b"])


@pytest.mark.parametrize("mode", MODES)
def test_negative_zero_float_keys_match_everywhere(mode):
    """IEEE says -0.0 == 0.0; the packed bitcast disagrees unless the
    packing normalizes — a probe 0.0 must match a build -0.0 on every
    path.  A build side holding both zeros is a GENUINE duplicate
    (IEEE-equal keys), so it now fans out as an m:n group instead of
    raising — and must NOT trip the f32-conflation guard."""
    lcols = {"key": np.array([0.0, 1.0]), "lv": np.arange(2.0)}
    rcols = {"key": np.array([-0.0, 1.0]), "rv": np.array([5.0, 6.0])}
    got = _got(_run_join(lcols, rcols, "key", "inner", mode))
    np.testing.assert_allclose(got["rv"], [5.0, 6.0])
    dup = {"key": np.array([0.0, -0.0]), "rv": np.array([1.0, 2.0])}
    got2 = _got(_run_join(lcols, dup, "key", "inner", mode))
    np.testing.assert_allclose(got2["key"], [0.0, 0.0])
    np.testing.assert_allclose(got2["rv"], [1.0, 2.0])


# ---------------------------------------------------------------------------
# m:n joins (duplicate build-side keys): groupbuilder expansion on every
# path, pandas-oracle parity, exact cross-path ordering, routing
# ---------------------------------------------------------------------------


def _mn_data(n=900, k=24, fanout_lo=1, fanout_hi=5, seed=11):
    r = np.random.RandomState(seed)
    reps = r.randint(fanout_lo, fanout_hi + 1, k)
    rcols = {"key": np.repeat(np.arange(k), reps).astype(np.int64)}
    nr = rcols["key"].size
    rcols["rv"] = r.rand(nr)
    rcols["ri"] = r.randint(0, 9, nr).astype(np.int64)
    lcols = {"key": r.randint(0, 2 * k, n).astype(np.int64),
             "lv": r.rand(n)}
    return lcols, rcols


@needs_pandas
@pytest.mark.parametrize("how", ["inner", "left"])
@pytest.mark.parametrize("mode", MODES)
def test_mn_join_pandas_parity(how, mode):
    lcols, rcols = _mn_data()
    want = pd_join(lcols, rcols, "key", how)
    got = _got(_run_join(lcols, rcols, "key", how, mode))
    assert set(got) == set(want)
    # row-SET parity (pandas orders matches differently); sizes first
    assert got["key"].shape == want["key"].shape
    cols = sorted(want)
    def keyed(d):
        return sorted(zip(*[np.asarray(d[c]).tolist() for c in cols]),
                      key=repr)
    for a, b in zip(keyed(got), keyed(want)):
        np.testing.assert_allclose(
            np.array(a, np.float64), np.array(b, np.float64),
            rtol=1e-12, equal_nan=True)


@pytest.mark.parametrize("how", ["inner", "left"])
def test_mn_join_exact_order_across_paths(how):
    """All three lazy paths must equal the eager oracle EXACTLY —
    probe-row-major, matches within a probe row in build-row order."""
    lcols, rcols = _mn_data(n=400, k=12, seed=3)
    ref = _got(_run_join(lcols, rcols, "key", how, "eager"))
    for mode in ("off", "auto", "always"):
        got = _got(_run_join(lcols, rcols, "key", how, mode))
        for c in ref:
            np.testing.assert_array_equal(got[c], ref[c],
                                          err_msg=f"{how}/{mode}/{c}")


@needs_pandas
@pytest.mark.parametrize("how", ["inner", "left"])
@pytest.mark.parametrize("mode", MODES)
def test_mn_multi_key_filtered_parity(how, mode):
    r = np.random.RandomState(8)
    lcols = {"a": r.randint(0, 6, 500).astype(np.int64),
             "b": r.randint(0, 3, 500).astype(np.int64),
             "lv": r.rand(500)}
    rcols = {"a": np.repeat(np.arange(5), 6).astype(np.int64),
             "b": np.tile(np.arange(3), 10).astype(np.int64),  # dups!
             "rv": r.rand(30)}
    m = lcols["lv"] > 0.4
    want = pd_join(lcols, rcols, ["a", "b"], how, m=m)
    got = _got(_run_join(lcols, rcols, ["a", "b"], how, mode,
                         pred_col="lv", pred_thresh=0.4))
    assert got["a"].shape == want["a"].shape
    cols = sorted(want)
    def keyed(d):
        return sorted(zip(*[np.asarray(d[c]).tolist() for c in cols]),
                      key=repr)
    for a, b in zip(keyed(got), keyed(want)):
        np.testing.assert_allclose(
            np.array(a, np.float64), np.array(b, np.float64),
            rtol=1e-12, equal_nan=True)


@pytest.mark.parametrize("how", ["inner", "left"])
@pytest.mark.parametrize("mode", MODES)
def test_mn_fanout_32_and_empty_probe(how, mode):
    r = np.random.RandomState(9)
    rcols = {"key": np.repeat(np.arange(4), 32).astype(np.int64),
             "rv": r.rand(128)}
    lcols = {"key": r.randint(0, 8, 60).astype(np.int64), "lv": r.rand(60)}
    ref = _got(_run_join(lcols, rcols, "key", how, "eager"))
    got = _got(_run_join(lcols, rcols, "key", how, mode))
    for c in ref:
        np.testing.assert_array_equal(got[c], ref[c])
    sel = np.isin(lcols["key"], rcols["key"])
    want_rows = 32 * int(sel.sum()) + (0 if how == "inner"
                                       else int((~sel).sum()))
    assert got["key"].shape[0] == want_rows
    # empty probe side
    empty = {c: v[:0] for c, v in lcols.items()}
    got0 = _got(_run_join(empty, rcols, "key", how, mode))
    assert all(v.size == 0 for v in got0.values())


@pytest.mark.parametrize("mode", MODES)
def test_mn_all_miss_left_fill_dtypes(mode):
    """m:n build side, every probe key missing: left keeps each row once
    with per-dtype sentinel fills (incl. bool, which the m:n gather
    path carries natively, no i8 encode)."""
    lcols = {"key": np.array([100, 101, 102], np.int64)}
    rcols = {"key": np.array([1, 1, 2], np.int64),
             "f": np.array([0.5, 0.25, 0.125]),
             "i": np.array([3, 4, 5], np.int64),
             "g": np.array([True, False, True])}
    got = _got(_run_join(lcols, rcols, "key", "left", mode))
    np.testing.assert_array_equal(got["key"], lcols["key"])
    assert got["f"].dtype == np.float64 and np.isnan(got["f"]).all()
    assert got["i"].dtype == np.int64 and (got["i"] == 0).all()
    assert got["g"].dtype == np.bool_ and (~got["g"]).all()
    gi = _got(_run_join(lcols, rcols, "key", "inner", mode))
    assert all(v.size == 0 for v in gi.values())


def test_mn_join_routes_one_group_build_and_probe():
    """An m:n join under kernelize='always' must launch exactly ONE
    group_build and ONE group_probe, whatever the output width."""
    lcols, rcols = _mn_data()
    st: dict = {}
    out = _run_join(lcols, rcols, "key", "inner", "always",
                    collect_stats=st)
    assert len(out.cols) == 4
    assert st.get("kernelize.group_build", 0) == 1, st.get("kernelplan")
    assert st.get("kernelize.group_probe", 0) == 1, st.get("kernelplan")
    assert st.get("kernelize.hash_probe", 0) == 0
    # m:1 joins must keep the dictmerger route (no group expansion)
    uniq = {"key": np.arange(24, dtype=np.int64),
            "rv": np.random.RandomState(0).rand(24)}
    st2: dict = {}
    _run_join(lcols, uniq, "key", "inner", "always", collect_stats=st2)
    assert st2.get("kernelize.group_probe", 0) == 0
    assert st2.get("kernelize.hash_probe", 0) == 1


@pytest.mark.parametrize("how", ["inner", "left"])
def test_mn_join_interpret_impl_parity(how):
    lcols, rcols = _mn_data(n=300, k=10, seed=21)
    outs = {}
    for impl in ("ref", "interpret"):
        t = weldrel.Table(lcols, eager=False)
        r = weldrel.Table(rcols, eager=False)
        outs[impl] = _got(weldrel.Query(t).join(
            r, on="key", how=how, kernelize="always", kernel_impl=impl))
    for c in outs["ref"]:
        np.testing.assert_allclose(outs["ref"][c], outs["interpret"][c],
                                   equal_nan=True)


# ---------------------------------------------------------------------------
# kernel-level parity: ref oracle vs interpreted Pallas kernels for the
# group build / probe pair, plus poison/overflow propagation
# ---------------------------------------------------------------------------


def test_group_build_contract_both_impls():
    from repro.kernels import ops as kops
    from repro.kernels.hash_table import EMPTY

    keys = np.concatenate([
        rng.randint(-30, 30, 300).astype(np.int64) * 999_983,
        np.array([EMPTY] * 5, np.int64),
    ])
    rng.shuffle(keys)
    valid = keys != EMPTY
    cap = np.unique(keys[valid]).size
    got = {}
    for impl in ("ref", "interpret"):
        cs, offs, used = map(np.asarray, kops.group_build(
            np.asarray(keys), cap, impl=impl))
        got[impl] = (cs, offs, used)
        assert used == cap
        assert (cs[~valid] == cap).all()
        uk = np.unique(keys[valid])
        for s, kk in enumerate(uk):
            # equal keys share one slot; slots ascend with key order;
            # CSR sizes equal the per-key multiplicities
            assert (cs[keys == kk] == s).all()
            assert offs[s + 1] - offs[s] == (keys == kk).sum()
        assert offs[0] == 0 and offs[cap] == valid.sum()
    for a, b in zip(got["ref"], got["interpret"]):
        np.testing.assert_array_equal(a, b)


def test_group_probe_parity_both_impls():
    from repro.kernels import ops as kops

    cap, count = 48, 32
    table = np.sort(rng.choice(5000, count, replace=False)).astype(np.int64)
    table = np.concatenate([table, np.full(cap - count, 88_888, np.int64)])
    big = np.iinfo(np.int64).max
    neut = np.where(np.arange(cap) < count, table, big)
    sizes = rng.randint(1, 6, cap)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    queries = rng.randint(0, 5000, 400).astype(np.int64)
    got = {}
    for impl in ("ref", "interpret"):
        pos, found, sz = map(np.asarray, kops.group_probe(
            neut, offsets, count, queries, impl=impl))
        got[impl] = (pos, found, sz)
        want_found = np.isin(queries, table[:count])
        np.testing.assert_array_equal(found, want_found)
        np.testing.assert_array_equal(table[pos[found]], queries[found])
        np.testing.assert_array_equal(sz[found], sizes[pos[found]])
        assert (pos[~found] == 0).all() and (sz[~found] == 0).all()
    for a, b in zip(got["ref"], got["interpret"]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_group_build_overflow_poisons_and_probe_propagates(impl):
    """More distinct build keys than the builder capacity: the group
    build flags a NEGATIVE count, decode raises, and a probe against
    the poisoned group propagates count=-1 into its output vector."""
    import jax.numpy as jnp

    from repro.core.backend.values import WVec
    from repro.core.kernelplan import registry as kreg

    keys = WVec(jnp.asarray(np.arange(64, dtype=np.int64) * 3))
    params = {"capacity": 16, "n_keys": 1, "key_nps": ("int64",),
              "has_pred": False}
    fns = [lambda i, x: x, lambda i, x: i]
    g = kreg._exec_group_build([keys], params, fns, impl)
    assert int(np.asarray(g.count)) < 0
    with pytest.raises(RuntimeError, match="distinct keys"):
        g.to_numpy()
    probe = WVec(jnp.asarray(np.arange(10, dtype=np.int64)))
    pparams = {"how": "inner", "n_keys": 1, "n_iters": 1,
               "cols": (("expr", 0),), "fills": (None,), "out_cap": 10,
               "has_pred": False}
    pfns = [lambda i, x: x, lambda i, x: x]
    outs = kreg._exec_group_probe([g, probe], pparams, pfns, impl)
    assert int(np.asarray(outs[0].count)) == -1
    with pytest.raises(RuntimeError, match="poisoned"):
        outs[0].to_numpy()


def test_composed_dict_build_parity_ref_vs_interpret():
    """The full build pipeline (hash/sort -> segment -> compaction) must
    produce identical sorted dicts from both slot-assignment impls."""
    lcols = {"key": rng.randint(0, 40, 400).astype(np.int64),
             "lv": rng.rand(400)}
    rcols = {"key": np.arange(40, dtype=np.int64), "rv": rng.rand(40)}
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    a = weldrel.Query(t).join(r, on="key", kernelize="always",
                              kernel_impl="ref")
    b = weldrel.Query(t).join(r, on="key", kernelize="always",
                              kernel_impl="interpret")
    for c in a.cols:
        np.testing.assert_allclose(_got(a)[c], _got(b)[c], rtol=1e-12)
