"""Hash-join correctness and routing: weldrel.Query.join against a NumPy
oracle on the eager, lazy-generic, and kernelized paths; kernel-level
ref/interpret parity for the open-addressing build and the one-hot
probe; planner routing decisions (probed dicts take the hash route, the
dense group-by route is untouched, the cost gate rejects tiny inputs)."""
import numpy as np
import pytest

from repro.frames import weldrel

rng = np.random.RandomState(13)


def np_join(lcols, rcols, on, m=None):
    """m:1 inner-join oracle; right keys must be unique."""
    lk, rk = lcols[on], rcols[on]
    mask = np.ones(lk.shape[0], bool) if m is None else m
    order = np.argsort(rk, kind="stable")
    rks = rk[order]
    if rks.size:
        pos = np.clip(np.searchsorted(rks, lk), 0, rks.size - 1)
        found = rks[pos] == lk
    else:
        found = np.zeros(lk.shape[0], bool)
    sel = mask & found
    out = {c: v[sel] for c, v in lcols.items()}
    if rks.size:
        gidx = order[pos[sel]]
        for c, v in rcols.items():
            if c != on:
                out[c] = v[gidx]
    else:
        for c, v in rcols.items():
            if c != on:
                out[c] = v[:0]
    return out


def _got(table):
    return {c: np.asarray(weldrel._host(table.cols[c])) for c in table.cols}


def _check(table, want):
    got = _got(table)
    assert set(got) == set(want)
    for c in want:
        np.testing.assert_allclose(got[c], want[c], rtol=1e-12)


def _data(n=1500, k=64, key_lo=0, key_hi=100, scale=1):
    lcols = {"key": (rng.randint(key_lo, key_hi, n) * scale).astype(np.int64),
             "lv": rng.rand(n)}
    rcols = {"key": (np.arange(k) * scale).astype(np.int64),
             "rv": rng.rand(k),
             "rw": rng.randint(0, 9, k).astype(np.int64)}
    return lcols, rcols


# ---------------------------------------------------------------------------
# oracle parity on all three execution paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["eager", "off", "always", "auto"])
def test_join_matches_numpy_oracle(mode):
    lcols, rcols = _data()
    want = np_join(lcols, rcols, "key")
    if mode == "eager":
        t = weldrel.Table(lcols, eager=True)
        r = weldrel.Table(rcols, eager=True)
        out = weldrel.Query(t).join(r, on="key")
    else:
        t = weldrel.Table(lcols, eager=False)
        r = weldrel.Table(rcols, eager=False)
        out = weldrel.Query(t).join(r, on="key", kernelize=mode)
    _check(out, want)


def test_join_kernelized_routes_and_matches():
    lcols, rcols = _data()
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    st: dict = {}
    out = weldrel.Query(t).join(r, on="key", kernelize="always",
                                collect_stats=st)
    assert st["kernelize.dict_hash_build"] == 1
    assert st["kernelize.hash_probe"] == 4  # key, lv, rv, rw
    _check(out, np_join(lcols, rcols, "key"))


def test_join_with_filter_predicate():
    lcols, rcols = _data()
    for eager in (False, True):
        t = weldrel.Table(lcols, eager=eager)
        r = weldrel.Table(rcols, eager=eager)
        q = weldrel.Query(t).filter(t.col("lv") > 0.5)
        kw = {} if eager else {"kernelize": "always"}
        out = q.join(r, on="key", **kw)
        _check(out, np_join(lcols, rcols, "key", m=lcols["lv"] > 0.5))


def test_join_sparse_keys_kernelized():
    """Keys far outside any dense [0, capacity) range: the dense group-by
    route would poison these — the hash route must handle them."""
    lcols, rcols = _data(scale=1_000_003)
    lcols["key"] -= 5  # include negative-ish offsets of the lattice
    rcols["key"] -= 5
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    st: dict = {}
    out = weldrel.Query(t).join(r, on="key", kernelize="always",
                                collect_stats=st)
    assert st["kernelize.dict_hash_build"] == 1
    _check(out, np_join(lcols, rcols, "key"))


def test_join_duplicate_probe_keys_and_misses():
    lcols = {"key": np.array([3, 3, 3, 99, 5, 3], np.int64),
             "lv": np.arange(6.0)}
    rcols = {"key": np.array([5, 3], np.int64), "rv": np.array([0.5, 0.25])}
    want = np_join(lcols, rcols, "key")
    for mode in ("eager", "off", "always"):
        if mode == "eager":
            out = weldrel.Query(weldrel.Table(lcols, eager=True)).join(
                weldrel.Table(rcols, eager=True), on="key")
        else:
            out = weldrel.Query(weldrel.Table(lcols, eager=False)).join(
                weldrel.Table(rcols, eager=False), on="key", kernelize=mode)
        _check(out, want)


@pytest.mark.parametrize("which", ["left", "right", "both"])
def test_join_empty_sides(which):
    lcols, rcols = _data(n=200, k=16)
    if which in ("left", "both"):
        lcols = {c: v[:0] for c, v in lcols.items()}
    if which in ("right", "both"):
        rcols = {c: v[:0] for c, v in rcols.items()}
    want = np_join(lcols, rcols, "key")
    for mode in ("eager", "off", "always"):
        if mode == "eager":
            out = weldrel.Query(weldrel.Table(lcols, eager=True)).join(
                weldrel.Table(rcols, eager=True), on="key")
        else:
            out = weldrel.Query(weldrel.Table(lcols, eager=False)).join(
                weldrel.Table(rcols, eager=False), on="key", kernelize=mode)
        got = _got(out)
        assert all(got[c].size == 0 for c in got)
        assert set(got) == set(want)


@pytest.mark.parametrize("eager", [True, False])
def test_join_duplicate_build_keys_raise(eager):
    t = weldrel.Table({"key": np.array([1, 2], np.int64)}, eager=eager)
    r = weldrel.Table({"key": np.array([7, 7], np.int64),
                       "rv": np.zeros(2)}, eager=eager)
    with pytest.raises(ValueError, match="unique build-side keys"):
        weldrel.Query(t).join(r, on="key")


def test_join_suffix_and_right_on():
    lcols = {"k": np.array([1, 2, 3], np.int64), "v": np.arange(3.0)}
    rcols = {"rk": np.array([2, 3], np.int64), "v": np.array([9.0, 8.0])}
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    out = weldrel.Query(t).join(r, on="k", right_on="rk", kernelize="off")
    got = _got(out)
    assert set(got) == {"k", "v", "v_r"}
    np.testing.assert_array_equal(got["k"], [2, 3])
    np.testing.assert_allclose(got["v_r"], [9.0, 8.0])


def test_join_interpret_impl_parity():
    lcols, rcols = _data(n=300, k=16)
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    a = weldrel.Query(t).join(r, on="key", kernelize="always",
                              kernel_impl="ref")
    b = weldrel.Query(t).join(r, on="key", kernelize="always",
                              kernel_impl="interpret")
    for c in a.cols:
        np.testing.assert_allclose(_got(a)[c], _got(b)[c], rtol=1e-12)


def test_join_rejects_unsupported_shapes():
    t = weldrel.Table({"k": np.array([1], np.int64)})
    r = weldrel.Table({"k": np.array([1], np.int64)})
    with pytest.raises(NotImplementedError):
        weldrel.Query(t).join(r, on="k", how="left")
    with pytest.raises(TypeError):
        weldrel.Query(t).join(weldrel.Query(r), on="k")


def test_join_keys_beyond_32_bits_do_not_conflate():
    """Single int key columns pack full-width: keys that agree in the
    low 32 bits (e.g. 1 vs 2^32+1) must not be conflated on any path."""
    lcols = {"key": np.array([1, 2 ** 32 + 1, 5], np.int64),
             "lv": np.arange(3.0)}
    rcols = {"key": np.array([2 ** 32 + 1], np.int64),
             "rv": np.array([7.0])}
    want = np_join(lcols, rcols, "key")
    assert want["key"].tolist() == [2 ** 32 + 1]
    for mode in ("eager", "off", "always"):
        if mode == "eager":
            out = weldrel.Query(weldrel.Table(lcols, eager=True)).join(
                weldrel.Table(rcols, eager=True), on="key")
        else:
            out = weldrel.Query(weldrel.Table(lcols, eager=False)).join(
                weldrel.Table(rcols, eager=False), on="key", kernelize=mode)
        _check(out, want)


@pytest.mark.parametrize("eager", [True, False])
def test_join_undersized_capacity_raises(eager):
    lcols = {"key": np.arange(10, dtype=np.int64)}
    rcols = {"key": np.arange(8, dtype=np.int64), "rv": rng.rand(8)}
    t = weldrel.Table(lcols, eager=eager)
    r = weldrel.Table(rcols, eager=eager)
    with pytest.raises(ValueError, match="capacity"):
        weldrel.Query(t).join(r, on="key", capacity=4)


# ---------------------------------------------------------------------------
# routing decisions
# ---------------------------------------------------------------------------


def test_probe_not_routed_beyond_vmem_capacity():
    """A build side beyond the hash kernels' capacity bound must keep
    BOTH sides on the generic lowering under kernelize='always' — the
    probe's one-hot tile cannot exceed its VMEM budget either."""
    from repro.kernels.hash_table import MAX_CAP

    k = MAX_CAP + 512
    n = 4096
    lcols = {"key": rng.randint(0, k, n).astype(np.int64), "lv": rng.rand(n)}
    rcols = {"key": np.arange(k, dtype=np.int64), "rv": rng.rand(k)}
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    st: dict = {}
    out = weldrel.Query(t).join(r, on="key", kernelize="always",
                                collect_stats=st)
    assert st.get("kernelize.dict_hash_build", 0) == 0, st.get("kernelplan")
    assert st.get("kernelize.hash_probe", 0) == 0, st.get("kernelplan")
    _check(out, np_join(lcols, rcols, "key"))


def test_join_auto_routes_large_and_rejects_tiny():
    n, k = 300_000, 20_000
    lcols = {"key": rng.randint(0, 2 * k, n).astype(np.int64),
             "lv": rng.rand(n)}
    rcols = {"key": np.arange(k, dtype=np.int64), "rv": rng.rand(k)}
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    st: dict = {}
    out = weldrel.Query(t).join(r, on="key", kernelize="auto",
                                collect_stats=st)
    assert st.get("kernelize.dict_hash_build", 0) == 1, st.get("kernelplan")
    assert st.get("kernelize.hash_probe", 0) >= 1, st.get("kernelplan")
    _check(out, np_join(lcols, rcols, "key"))
    # tiny inputs: padding + launch overhead dominate -> gate keeps jnp
    lcols2, rcols2 = _data(n=100, k=8)
    st2: dict = {}
    out2 = weldrel.Query(weldrel.Table(lcols2, eager=False)).join(
        weldrel.Table(rcols2, eager=False), on="key", kernelize="auto",
        collect_stats=st2)
    assert st2["kernelize.matched"] == 0, st2.get("kernelplan")
    assert st2["kernelplan"]["rejected"].get("hash_probe", 0) >= 1
    _check(out2, np_join(lcols2, rcols2, "key"))


def test_groupby_hash_route_beyond_dense_capacity():
    """Capacities beyond the dense segment tile (4096) used to fall back
    to the generic sort path; the hash route now serves them."""
    from repro.frames import welddf

    n = 50_000
    key = rng.randint(0, 20_000, n).astype(np.int64)
    val = rng.rand(n)
    df = welddf.DataFrame({"k": key, "v": val})
    st: dict = {}
    d1 = df.groupby_sum("k", "v", capacity=32768, kernelize=True,
                        collect_stats=st)
    assert st["kernelize.dict_hash_build"] == 1
    d0 = df.groupby_sum("k", "v", capacity=32768, kernelize=False)
    assert set(d1) == set(d0)
    for kk in d0:
        np.testing.assert_allclose(d1[kk], d0[kk], rtol=1e-10)


def test_dense_groupby_route_unchanged():
    """Probing is what selects the hash build; a plain in-range group-by
    must still take the dense segment route."""
    from repro.frames import welddf

    key = rng.randint(0, 50, 4096).astype(np.int64)
    val = rng.rand(4096)
    df = welddf.DataFrame({"k": key, "v": val})
    st: dict = {}
    df.groupby_sum("k", "v", capacity=64, kernelize=True, collect_stats=st)
    assert st.get("kernelize.dict_group_sum", 0) == 1
    assert st.get("kernelize.dict_hash_build", 0) == 0


def test_hash_build_sparse_keys_decode_correctly():
    """Sparse keys through the hash route: capacity 4097 skips the dense
    route (tile bound 4096) and lands on dict_hash_build; the decoded
    dict must agree with the generic lowering."""
    from repro.core import ir, macros as M
    from repro.core.lazy import Evaluate, NewWeldObject

    keys = NewWeldObject(np.arange(100, dtype=np.int64) * 11, None)
    vals = NewWeldObject(rng.rand(100), None)
    kid = ir.Ident(keys.obj_id, keys.weld_type())
    vid = ir.Ident(vals.obj_id, vals.weld_type())
    d = M.groupby_agg(kid, vid, "+", capacity=4097)
    obj = NewWeldObject([keys, vals], d)
    st: dict = {}
    out = Evaluate(obj, kernelize="always", collect_stats=st)
    assert st.get("kernelize.dict_hash_build", 0) == 1
    assert len(out.value) == 100
    want = Evaluate(obj, kernelize=False).value
    assert set(out.value) == set(want)
    for kk in want:
        np.testing.assert_allclose(out.value[kk], want[kk], rtol=1e-10)


def test_hash_build_overflow_raises_on_decode():
    from repro.core import ir, macros as M
    from repro.core.lazy import Evaluate, NewWeldObject

    keys = NewWeldObject(np.arange(8000, dtype=np.int64) * 3, None)
    vals = NewWeldObject(rng.rand(8000), None)
    kid = ir.Ident(keys.obj_id, keys.weld_type())
    vid = ir.Ident(vals.obj_id, vals.weld_type())
    d = M.groupby_agg(kid, vid, "+", capacity=4097)  # 8000 distinct > 4097
    obj = NewWeldObject([keys, vals], d)
    with pytest.raises(RuntimeError):
        Evaluate(obj, kernelize="always")


# ---------------------------------------------------------------------------
# kernel-level parity: ref oracle vs interpreted Pallas kernels
# ---------------------------------------------------------------------------


def test_hash_to_slot_contract_both_impls():
    from repro.kernels import ops as kops
    from repro.kernels.hash_table import EMPTY, table_size

    keys = np.concatenate([
        rng.randint(-50, 50, 300).astype(np.int64) * 999_983,
        np.array([EMPTY] * 5, np.int64),
    ])
    rng.shuffle(keys)
    C = table_size(128)
    for impl in ("ref", "interpret"):
        slots, table, used = map(np.asarray, kops.hash_to_slot(
            np.asarray(keys), C, impl=impl))
        valid = keys != EMPTY
        assert used == np.unique(keys[valid]).size
        assert (slots[~valid] == C).all()
        assert (table[slots[valid]] == keys[valid]).all()
        # distinct keys -> distinct slots
        uniq = {}
        for kk, s in zip(keys[valid], slots[valid]):
            assert uniq.setdefault(kk, s) == s
        assert len(set(uniq.values())) == len(uniq)


def test_dict_probe_parity_both_impls():
    from repro.kernels import ops as kops

    cap, count = 64, 40
    table = np.sort(rng.choice(10_000, count, replace=False)).astype(np.int64)
    table = np.concatenate([table, np.full(cap - count, 77_777, np.int64)])
    big = np.iinfo(np.int64).max
    neut = np.where(np.arange(cap) < count, table, big)
    queries = rng.randint(0, 10_000, 500).astype(np.int64)
    got = {}
    for impl in ("ref", "interpret"):
        pos, found = map(np.asarray, kops.dict_probe(
            neut, count, queries, impl=impl))
        got[impl] = (pos, found)
        want_found = np.isin(queries, table[:count])
        np.testing.assert_array_equal(found, want_found)
        np.testing.assert_array_equal(
            table[pos[found]], queries[found])
        assert (pos[~found] == 0).all()
    np.testing.assert_array_equal(got["ref"][0], got["interpret"][0])


def test_composed_dict_build_parity_ref_vs_interpret():
    """The full build pipeline (hash/sort -> segment -> compaction) must
    produce identical sorted dicts from both slot-assignment impls."""
    lcols = {"key": rng.randint(0, 40, 400).astype(np.int64),
             "lv": rng.rand(400)}
    rcols = {"key": np.arange(40, dtype=np.int64), "rv": rng.rand(40)}
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    a = weldrel.Query(t).join(r, on="key", kernelize="always",
                              kernel_impl="ref")
    b = weldrel.Query(t).join(r, on="key", kernelize="always",
                              kernel_impl="interpret")
    for c in a.cols:
        np.testing.assert_allclose(_got(a)[c], _got(b)[c], rtol=1e-12)
