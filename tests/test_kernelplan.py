"""Kernel planner golden tests: fused IR loops route onto the Pallas
kernel library, mismatches fall back to the jnp emitter unchanged, and
kernelized results agree with the generic backend."""
import numpy as np
import pytest

from repro.core import ir, macros as M, wtypes as wt
from repro.core import kernelplan as kp
from repro.core.lazy import Evaluate, NewWeldObject, build_program
from repro.core.passes import optimize

rng = np.random.RandomState(42)
N = 4096


def _ident(o):
    return ir.Ident(o.obj_id, o.weld_type())


def _q6_like_obj(n=N):
    """Fused filter+reduce: sum(price*disc where price < 0.5)."""
    price = NewWeldObject(rng.rand(n), None)
    disc = NewWeldObject(rng.rand(n), None)
    expr = M.filter_reduce(
        M.zip_map([_ident(price), _ident(disc)],
                  lambda p, d: ir.MakeStruct((p, d))),
        lambda x: ir.BinOp("<", ir.GetField(x, 0), ir.Literal(0.5, wt.F64)),
        "+",
        lambda x: ir.BinOp("*", ir.GetField(x, 0), ir.GetField(x, 1)),
    )
    obj = NewWeldObject([price, disc], expr)
    want = (np.asarray(price.data) * np.asarray(disc.data))[
        np.asarray(price.data) < 0.5
    ].sum()
    return obj, want


# ---------------------------------------------------------------------------
# golden: optimized programs are annotated with the expected KernelCall
# ---------------------------------------------------------------------------


def test_planner_annotates_q6_filter_reduce():
    obj, _ = _q6_like_obj()
    prog = build_program(obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    stats: dict = {}
    planned = kp.plan_kernels(opt, input_shapes=shapes, stats=stats)
    calls = [n for n in ir.walk(planned) if isinstance(n, ir.KernelCall)]
    assert stats["kernelize.matched"] == 1
    assert stats["kernelize.filter_reduce_sum"] == 1
    assert [c.kernel for c in calls] == ["filter_reduce_sum"]
    assert dict(calls[0].params)["has_pred"] is True


def test_planner_annotates_segment_reduce():
    """PageRank-style vecmerger scatter routes to segment_sum."""
    idxs = NewWeldObject(rng.randint(0, 100, N).astype(np.int64), None)
    vals = NewWeldObject(rng.rand(N), None)
    base = NewWeldObject(np.zeros(100), None)
    expr = M.scatter_add(_ident(base), _ident(idxs), _ident(vals))
    obj = NewWeldObject([base, idxs, vals], expr)
    prog = build_program(obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    stats: dict = {}
    planned = kp.plan_kernels(opt, input_shapes=shapes, stats=stats)
    assert stats.get("kernelize.vecmerger_segment_sum", 0) == 1
    assert any(isinstance(n, ir.KernelCall) for n in ir.walk(planned))


def test_planner_annotates_dict_groupby():
    keys = NewWeldObject(rng.randint(0, 32, N).astype(np.int64), None)
    vals = NewWeldObject(rng.rand(N), None)
    expr = M.groupby_agg(_ident(keys), _ident(vals), "+", capacity=64)
    obj = NewWeldObject([keys, vals], expr)
    prog = build_program(obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    stats: dict = {}
    kp.plan_kernels(opt, input_shapes=shapes, stats=stats)
    assert stats.get("kernelize.dict_group_sum", 0) == 1


def test_planner_annotates_matmul():
    from repro.frames import weldnp

    a = weldnp.array(rng.rand(32, 16))
    b = weldnp.array(rng.rand(16, 8))
    prog = build_program(a.dot(b).obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    stats: dict = {}
    kp.plan_kernels(opt, input_shapes=shapes, stats=stats)
    assert stats.get("kernelize.matmul", 0) == 1


# ---------------------------------------------------------------------------
# end-to-end parity: kernelized == jnp-only
# ---------------------------------------------------------------------------


def test_q6_kernelized_matches_jnp():
    obj, want = _q6_like_obj()
    st: dict = {}
    r1 = Evaluate(obj, kernelize=True, collect_stats=st)
    r0 = Evaluate(obj, kernelize=False)
    assert st["kernelize.filter_reduce_sum"] == 1
    np.testing.assert_allclose(r1.value, r0.value, rtol=1e-12)
    np.testing.assert_allclose(r1.value, want, rtol=1e-10)


def test_reduce_without_filter_kernelized():
    """Unconditional map+reduce (Black-Scholes shape) also routes."""
    x = rng.rand(N)
    xo = NewWeldObject(x, None)
    expr = M.reduce_(
        M.map_(_ident(xo), lambda v: ir.BinOp(
            "*", ir.UnaryOp("exp", v), ir.Literal(2.0, wt.F64))),
        "+",
    )
    obj = NewWeldObject([xo], expr)
    st: dict = {}
    r1 = Evaluate(obj, kernelize=True, collect_stats=st)
    assert st["kernelize.filter_reduce_sum"] == 1
    assert dict(
        [(k, v) for k, v in st.items() if k == "kernelize.matched"]
    )["kernelize.matched"] == 1
    np.testing.assert_allclose(r1.value, (np.exp(x) * 2.0).sum(), rtol=1e-10)


def test_segment_reduce_kernelized_matches_jnp():
    idxs = rng.randint(0, 100, N).astype(np.int64)
    vals = rng.rand(N)
    base = rng.rand(100)
    io = NewWeldObject(idxs, None)
    vo = NewWeldObject(vals, None)
    bo = NewWeldObject(base, None)
    expr = M.scatter_add(_ident(bo), _ident(io), _ident(vo))
    obj = NewWeldObject([bo, io, vo], expr)
    st: dict = {}
    r1 = np.asarray(Evaluate(obj, kernelize=True, collect_stats=st).value)
    r0 = np.asarray(Evaluate(obj, kernelize=False).value)
    assert st["kernelize.vecmerger_segment_sum"] == 1
    np.testing.assert_allclose(r1, r0, rtol=1e-12)
    want = base.copy()
    np.add.at(want, idxs, vals)
    np.testing.assert_allclose(r1, want, rtol=1e-10)


def test_groupby_kernelized_matches_jnp():
    from repro.frames import welddf

    state = rng.randint(0, 50, N).astype(np.int64)
    crime = rng.rand(N)
    df = welddf.DataFrame({"state": state, "crime": crime})
    st: dict = {}
    d1 = df.groupby_sum("state", "crime", capacity=64, kernelize=True,
                        collect_stats=st)
    d0 = df.groupby_sum("state", "crime", capacity=64, kernelize=False)
    assert st["kernelize.dict_group_sum"] == 1
    assert set(d1) == set(d0)
    for k in d0:
        np.testing.assert_allclose(d1[k], d0[k], rtol=1e-10)


def test_masked_groupby_kernelized_matches_jnp():
    from repro.frames import welddf

    state = rng.randint(0, 50, N).astype(np.int64)
    crime = rng.rand(N)
    df = welddf.DataFrame({"state": state, "crime": crime})
    fdf = df[df["crime"] > 0.5]
    st: dict = {}
    d1 = fdf.groupby_sum("state", "crime", capacity=64, kernelize=True,
                         collect_stats=st)
    d0 = fdf.groupby_sum("state", "crime", capacity=64, kernelize=False)
    assert st["kernelize.dict_group_sum"] == 1
    assert set(d1) == set(d0)
    for k in d0:
        np.testing.assert_allclose(d1[k], d0[k], rtol=1e-10)


def test_matmul_kernelized_matches_jnp():
    from repro.frames import weldnp

    A, B = rng.rand(48, 24), rng.rand(24, 16)
    wa, wb = weldnp.array(A), weldnp.array(B)
    st: dict = {}
    got = np.asarray(wa.dot(wb).evaluate(kernelize=True, collect_stats=st))
    assert st["kernelize.matmul"] == 1
    np.testing.assert_allclose(got.reshape(48, 16), A @ B, rtol=1e-12)


def test_map_chain_kernelized_matches_jnp():
    from repro.frames import weldnp

    x = rng.rand(N)
    wx = weldnp.array(x)
    y = weldnp.exp(wx * 2.0) + 1.0
    st: dict = {}
    got = np.asarray(y.evaluate(kernelize=True, collect_stats=st))
    assert st["kernelize.map_elementwise"] == 1
    np.testing.assert_allclose(got, np.exp(x * 2.0) + 1.0, rtol=1e-12)


# ---------------------------------------------------------------------------
# fallback: mismatches lower exactly as before
# ---------------------------------------------------------------------------


def test_non_plus_reduce_falls_back():
    """max-reduce has no kernel; planner must leave it to the emitter."""
    x = rng.rand(N)
    xo = NewWeldObject(x, None)
    expr = M.reduce_(_ident(xo), "max")
    obj = NewWeldObject([xo], expr)
    st: dict = {}
    r1 = Evaluate(obj, kernelize=True, collect_stats=st)
    assert st["kernelize.matched"] == 0
    np.testing.assert_allclose(r1.value, x.max(), rtol=1e-12)


def test_big_capacity_groupby_falls_back():
    """capacity beyond the VMEM tile bound must not route."""
    from repro.frames import welddf

    state = rng.randint(0, 50, N).astype(np.int64)
    crime = rng.rand(N)
    df = welddf.DataFrame({"state": state, "crime": crime})
    st: dict = {}
    d1 = df.groupby_sum("state", "crime", capacity=1 << 17, kernelize=True,
                        collect_stats=st)
    assert st["kernelize.matched"] == 0
    d0 = df.groupby_sum("state", "crime", capacity=1 << 17, kernelize=False)
    assert set(d1) == set(d0)


def test_default_capacity_groupby_routes():
    """The frames' default capacity (4096) must fit the kernel tile."""
    from repro.frames import welddf

    state = rng.randint(0, 50, N).astype(np.int64)
    crime = rng.rand(N)
    df = welddf.DataFrame({"state": state, "crime": crime})
    st: dict = {}
    d1 = df.groupby_sum("state", "crime", kernelize=True, collect_stats=st)
    assert st["kernelize.dict_group_sum"] == 1
    d0 = df.groupby_sum("state", "crime", kernelize=False)
    assert set(d1) == set(d0)
    for k in d0:
        np.testing.assert_allclose(d1[k], d0[k], rtol=1e-10)


def test_out_of_range_keys_recover_not_drop():
    """Keys outside [0, capacity) can't be represented by the dense-key
    route; the poison flag triggers the recovery ladder (regrow until
    the key fits, else generic fallback) instead of silently dropping
    rows.  With recovery disabled the typed CapacityError surfaces."""
    import warnings

    from repro.core import recovery
    from repro.core.errors import CapacityError
    from repro.frames import welddf

    key = np.array([100, 100, 1, 2], dtype=np.int64)
    val = np.array([1.0, 2.0, 3.0, 4.0])
    df = welddf.DataFrame({"k": key, "v": val})
    d0 = df.groupby_sum("k", "v", capacity=64, kernelize=False)
    assert d0 == {1: 3.0, 2: 4.0, 100: 3.0}
    st: dict = {}
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        d1 = df.groupby_sum("k", "v", capacity=64, kernelize=True,
                            collect_stats=st)
    assert d1 == d0
    assert st["recovery.attempts"] >= 2
    assert any("weld recovery" in str(x.message) for x in w)
    with recovery.disabled():
        with pytest.raises(CapacityError, match="outside \\[0, capacity\\)"):
            df.groupby_sum("k", "v", capacity=64, kernelize=True)


def test_float_key_groupby_falls_back():
    from repro.frames import welddf

    key = rng.rand(N)  # float keys: no dense-int routing
    val = rng.rand(N)
    df = welddf.DataFrame({"k": key, "v": val})
    st: dict = {}
    df.groupby_sum("k", "v", capacity=8192, kernelize=True, collect_stats=st)
    assert st["kernelize.matched"] == 0


def test_default_is_auto_and_off_disables_planning():
    """No knob -> cost-gated "auto" planning (stats carry the decision
    log); kernelize=False/"off" bypasses the planner entirely."""
    assert kp.DEFAULT_KERNELIZE == "auto"
    obj, want = _q6_like_obj()
    st: dict = {}
    r = Evaluate(obj, collect_stats=st)
    assert st["kernelplan"]["mode"] == "auto"
    assert st["kernelplan"]["costs"]  # every candidate was priced
    np.testing.assert_allclose(r.value, want, rtol=1e-10)
    st_off: dict = {}
    r0 = Evaluate(obj, kernelize="off", collect_stats=st_off)
    assert not any(k.startswith("kernel") for k in st_off)
    np.testing.assert_allclose(r0.value, want, rtol=1e-10)
    with pytest.raises(ValueError):
        Evaluate(obj, kernelize="sometimes")


# ---------------------------------------------------------------------------
# impl resolution: interpret (Pallas body on CPU) vs ref oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pattern", ["q6", "mapchain"])
def test_interpret_matches_ref(pattern):
    if pattern == "q6":
        obj, _ = _q6_like_obj(512)
        ri = Evaluate(obj, kernelize=True, kernel_impl="interpret")
        rr = Evaluate(obj, kernelize=True, kernel_impl="ref")
        np.testing.assert_allclose(ri.value, rr.value, rtol=1e-12)
    else:
        from repro.frames import weldnp

        x = rng.rand(512)
        wx = weldnp.array(x)
        y = weldnp.exp(wx * 2.0) + 1.0
        gi = np.asarray(y.evaluate(kernelize=True, kernel_impl="interpret"))
        gr = np.asarray(y.evaluate(kernelize=True, kernel_impl="ref"))
        np.testing.assert_allclose(gi, gr, rtol=1e-12)


def test_overflowed_dict_lookup_is_poisoned_not_plausible():
    """In-IR Lookup into an overflowed kernelized dict must not return a
    plausible-but-wrong number: float sums are NaN-poisoned and KeyExists
    sees no keys (host decode raises separately)."""
    keys = NewWeldObject(np.array([100, 1, 2], dtype=np.int64), None)
    vals = NewWeldObject(np.array([3.0, 3.0, 4.0]), None)
    d = M.groupby_agg(_ident(keys), _ident(vals), "+", capacity=8)
    obj = NewWeldObject([keys, vals], ir.Lookup(d, ir.Literal(2, wt.I64)))
    g0 = Evaluate(obj, kernelize=False).value
    assert g0 == 4.0
    assert np.isnan(Evaluate(obj, kernelize=True).value)
    d2 = M.groupby_agg(_ident(keys), _ident(vals), "+", capacity=8)
    obj2 = NewWeldObject([keys, vals],
                         ir.KeyExists(d2, ir.Literal(1, wt.I64)))
    assert not bool(Evaluate(obj2, kernelize=True).value)


def test_unregister_invalidates_compile_cache():
    """register/unregister is the ablation knob; a cached kernelized
    executable must not survive a registry change."""
    from repro.frames import welddf

    df = welddf.DataFrame({"k": np.array([1, 2, 2], dtype=np.int64),
                           "v": np.array([1.0, 2.0, 3.0])})
    st: dict = {}
    df.groupby_sum("k", "v", capacity=16, kernelize=True, collect_stats=st)
    assert st["kernelize.dict_group_sum"] == 1
    spec = kp.get("dict_group_sum")
    kp.unregister("dict_group_sum")
    try:
        st2: dict = {}
        d = df.groupby_sum("k", "v", capacity=16, kernelize=True,
                           collect_stats=st2)
        assert st2.get("kernelize.dict_group_sum", 0) == 0
        assert d == {1: 1.0, 2: 5.0}
    finally:
        kp.register(spec)


def test_program_evaluate_threads_kernelize():
    """lazy.Program.evaluate exposes the knob and the planner stats."""
    obj, want = _q6_like_obj()
    prog = build_program(obj)
    value, compile_ms, from_cache, stats = prog.evaluate(kernelize=True)
    assert stats["kernelize.filter_reduce_sum"] == 1
    np.testing.assert_allclose(np.asarray(value), want, rtol=1e-10)
    v0, *_ = prog.evaluate(kernelize=False)
    np.testing.assert_allclose(np.asarray(value), np.asarray(v0), rtol=1e-12)


def test_registry_describes_all_kernels():
    names = {s.name for s in kp.all_specs()}
    assert {"filter_reduce_sum", "vecmerger_segment_sum", "dict_group_sum",
            "matmul", "matvec", "map_elementwise"} <= names
    text = kp.describe()
    assert "repro.kernels.ops" in text


# ---------------------------------------------------------------------------
# cost gate (mode="auto"): tiny inputs reject, large dense inputs route,
# oversized vecmerger scatter rejects
# ---------------------------------------------------------------------------


def test_cost_gate_rejects_tiny_input():
    """Padding + launch overhead dominate a tiny reduce: the gate must
    keep the jnp lowering (and still compute the right answer)."""
    obj, want = _q6_like_obj(256)
    st: dict = {}
    r = Evaluate(obj, kernelize="auto", collect_stats=st)
    assert st["kernelize.matched"] == 0
    assert st["kernelplan"]["rejected"].get("filter_reduce_sum", 0) == 1
    (entry,) = st["kernelplan"]["costs"]
    assert entry["routed"] is False
    assert entry["kernel_us"] > entry["jnp_us"]  # the losing estimate
    np.testing.assert_allclose(r.value, want, rtol=1e-10)


def test_cost_gate_routes_large_dense_input():
    obj, want = _q6_like_obj(500_000)
    st: dict = {}
    r = Evaluate(obj, kernelize="auto", collect_stats=st)
    assert st["kernelize.filter_reduce_sum"] == 1
    assert st["kernelplan"]["routed"] == {"filter_reduce_sum": 1}
    np.testing.assert_allclose(r.value, want, rtol=1e-8)


def test_cost_gate_rejects_large_key_vecmerger():
    """K beyond the VMEM tile bound degrades the kernel route to the
    same scatter the jnp lowering does — auto must not route it."""
    n, k = 100_000, 50_000
    idxs = rng.randint(0, k, n).astype(np.int64)
    vals = rng.rand(n)
    base = np.zeros(k)
    io, vo, bo = (NewWeldObject(a, None) for a in (idxs, vals, base))
    expr = M.scatter_add(_ident(bo), _ident(io), _ident(vo))
    obj = NewWeldObject([bo, io, vo], expr)
    st: dict = {}
    r = np.asarray(Evaluate(obj, kernelize="auto", collect_stats=st).value)
    assert st["kernelize.matched"] == 0
    assert st["kernelplan"]["rejected"].get("vecmerger_segment_sum", 0) == 1
    want = base.copy()
    np.add.at(want, idxs, vals)
    np.testing.assert_allclose(r, want, rtol=1e-10)


def test_cost_gate_unknown_size_is_conservative():
    """A match whose iter length is not statically known cannot be
    priced — auto must fall back to jnp rather than gamble."""
    from repro.core.kernelplan import cost

    spec = kp.get("filter_reduce_sum")
    est = cost.estimate(spec, {"kernel": "filter_reduce_sum", "n": None})
    assert est.routed is False
    assert "unknown" in est.why


# ---------------------------------------------------------------------------
# autotune: cache hit / invalidate / fingerprint-keyed compile cache
# ---------------------------------------------------------------------------


@pytest.fixture
def tuner(tmp_path, monkeypatch):
    from repro.core.kernelplan import autotune

    monkeypatch.setenv(autotune.ENV_CACHE,
                       str(tmp_path / "autotune.json"))
    autotune.clear_cache(disk=False)
    yield autotune
    autotune.clear_cache(disk=False)


def test_autotune_times_grid_then_hits_cache(tuner, monkeypatch):
    spec = kp.get("filter_reduce_sum")
    meta = {"n": 2000, "dtype": np.float64}
    timed = []
    real = tuner._time_candidate
    monkeypatch.setattr(tuner, "_time_candidate",
                        lambda go: timed.append(1) or real(go))
    params, cached = tuner.tune(spec, meta, impl="interpret")
    assert not cached
    assert params["block"] in spec.tune_space["block"]
    assert len(timed) == len(spec.tune_space["block"])
    import json, os
    assert os.path.exists(tuner.cache_path())
    disk = json.load(open(tuner.cache_path()))
    assert any(k.startswith("filter_reduce_sum|float64|") for k in disk)
    # same size-bucket: cache hit, no re-timing
    timed.clear()
    params2, cached2 = tuner.tune(spec, {"n": 1800, "dtype": np.float64},
                                  impl="interpret")
    assert cached2 and params2 == params and not timed


def test_autotune_invalidate_and_fingerprint(tuner):
    spec = kp.get("filter_reduce_sum")
    f0 = tuner.fingerprint()
    tuner.tune(spec, {"n": 1500, "dtype": np.float64}, impl="interpret")
    f1 = tuner.fingerprint()
    assert f1 != f0  # new tuning must change the compile-cache key
    assert tuner.invalidate("filter_reduce_sum") == 1
    assert tuner.lookup("filter_reduce_sum", np.float64, 1500,
                        "interpret") is None
    assert tuner.fingerprint() != f1


def test_autotune_ref_impl_uses_defaults_without_cache(tuner):
    """The jnp oracle ignores block sizes: no timing, no cache writes."""
    spec = kp.get("filter_reduce_sum")
    params, cached = tuner.tune(spec, {"n": 4096, "dtype": np.float64},
                                impl="ref")
    assert params == spec.tune_defaults and not cached
    assert tuner.lookup("filter_reduce_sum", np.float64, 4096, "ref") is None


def test_tuned_plan_prints_block_shape():
    """pretty() surfaces the chosen block on KernelCall nodes."""
    from repro.core.kernelplan import autotune
    from repro.core.pretty import pretty

    obj, _ = _q6_like_obj(1024)
    prog = build_program(obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    planned = kp.plan_kernels(opt, input_shapes=shapes, stats={})
    tuned = autotune.tune_plan(planned, impl="ref")
    text = pretty(tuned)
    assert "kernel[filter_reduce_sum]@{block=" in text


# ---------------------------------------------------------------------------
# multi-aggregate fusion: one kernel launch for a struct of mergers
# ---------------------------------------------------------------------------


def test_multi_agg_fused_matches_per_aggregate_kernel():
    from repro.kernels import ops as kops

    vals = rng.rand(3, 5000)
    pred = rng.rand(5000) > 0.4
    fused = np.asarray(kops.filter_reduce_sum_multi(
        vals, pred, impl="interpret"))
    single = np.array([
        np.asarray(kops.filter_reduce_sum(vals[a], pred, impl="interpret"))
        for a in range(3)
    ])
    np.testing.assert_allclose(fused, single, rtol=1e-12)
    np.testing.assert_allclose(fused, vals[:, pred].sum(axis=1), rtol=1e-10)


def test_weldrel_multi_agg_is_one_kernel_call_and_parity():
    """Three aggregates over the same filtered scan: ONE filter_reduce
    launch (shared predicate mask + column loads), identical results to
    the jnp lowering and to the forced per-aggregate path."""
    from repro.frames import weldrel

    n = 4096
    c = {"a": rng.rand(n), "b": rng.rand(n), "p": rng.rand(n)}
    t = weldrel.Table(c)

    def agg(kernelize, st=None):
        q = weldrel.Query(t).filter(t.col("p") < 0.5)
        return q.agg({"x": (t.col("a"), "+"),
                      "y": (t.col("b"), "+"),
                      "z": (t.col("a") * t.col("b"), "+")},
                     kernelize=kernelize, collect_stats=st)

    st: dict = {}
    r1 = agg(True, st)
    assert st["kernelize.filter_reduce_sum"] == 1  # one call, three aggs
    r0 = agg(False)
    for key in ("x", "y", "z"):
        np.testing.assert_allclose(r1[key], r0[key], rtol=1e-10)
    # forced per-aggregate path (multi=False) agrees with the fused one
    mask = c["p"] < 0.5
    np.testing.assert_allclose(r1["x"], c["a"][mask].sum(), rtol=1e-10)
    np.testing.assert_allclose(r1["z"], (c["a"] * c["b"])[mask].sum(),
                               rtol=1e-10)


def test_multi_agg_forced_per_aggregate_path_parity():
    """The adapter's multi=False ablation param takes the per-aggregate
    path and must agree with the fused kernel."""
    from repro.core.backend.values import WVec
    from repro.core.kernelplan import registry as kreg

    n = 3000
    a, b = rng.rand(n), rng.rand(n)
    i = ir.Ident("i", wt.I64)
    x = ir.Ident("x", wt.Struct((wt.F64, wt.F64)))
    fns = [
        ir.Lambda((i, x), ir.GetField(x, 0)),
        ir.Lambda((i, x), ir.GetField(x, 1)),
        ir.Lambda((i, x), ir.BinOp("<", ir.GetField(x, 0),
                                   ir.Literal(0.5, wt.F64))),
    ]

    def run(multi):
        import jax.numpy as jnp
        from repro.core.backend.jaxgen import Emitter

        em = Emitter({}, None, kernel_impl="ref")
        staged = [em._stage_elem_fn(f, {}) for f in fns]
        return kreg.get("filter_reduce_sum").execute(
            [WVec(jnp.asarray(a)), WVec(jnp.asarray(b))],
            {"n_aggs": 2, "has_pred": True, "struct": True, "multi": multi},
            staged, "ref",
        )

    fused = [np.asarray(v) for v in run(True)]
    per_agg = [np.asarray(v) for v in run(False)]
    np.testing.assert_allclose(fused, per_agg, rtol=1e-12)
    mask = a < 0.5
    np.testing.assert_allclose(fused[0], a[mask].sum(), rtol=1e-10)
    np.testing.assert_allclose(fused[1], b[mask].sum(), rtol=1e-10)


# ---------------------------------------------------------------------------
# memory accounting: kernel padding/scratch feeds the memory_limit budget
# ---------------------------------------------------------------------------


def test_kernel_footprint_charged_to_memory_limit():
    from repro.core.backend.jaxgen import WeldMemoryError
    from repro.core.runtime import clear_cache

    obj, want = _q6_like_obj(8192)
    clear_cache()
    # generous limit: fine (and routed)
    st: dict = {}
    r = Evaluate(obj, kernelize=True, memory_limit=1 << 22, collect_stats=st)
    assert st["kernelize.filter_reduce_sum"] == 1
    np.testing.assert_allclose(r.value, want, rtol=1e-10)
    # tight limit: the kernelized plan's staging/padding must trip it...
    with pytest.raises(WeldMemoryError, match="kernel"):
        Evaluate(obj, kernelize=True, memory_limit=16 * 1024)
    # ...while the jnp-only lowering (no kernel scratch) stays within
    r0 = Evaluate(obj, kernelize=False, memory_limit=16 * 1024)
    np.testing.assert_allclose(r0.value, want, rtol=1e-10)


# ---------------------------------------------------------------------------
# stats contract: documented key namespaces (loops.*, kernelize.*,
# kernelplan.*, compile_ms) survive cache hit vs miss, and the returned
# stats are a COPY — caller-side mutation must never poison the cache
# ---------------------------------------------------------------------------

DOCUMENTED_STATS = ("loops.before", "loops.after", "kernelize.matched",
                    "kernelplan", "compile_ms", "bounds.certificate",
                    "bounds.peak_bytes", "bounds.admitted")


def test_stats_namespaces_survive_cache_hit_and_miss():
    from repro.core import runtime

    runtime.clear_cache()
    obj, _ = _q6_like_obj(2777)
    st_miss: dict = {}
    r_miss = Evaluate(obj, kernelize=True, collect_stats=st_miss)
    assert r_miss.from_cache is False
    st_hit: dict = {}
    r_hit = Evaluate(obj, kernelize=True, collect_stats=st_hit)
    assert r_hit.from_cache is True
    for key in DOCUMENTED_STATS:
        assert key in st_miss, f"miss stats lost {key}"
        assert key in st_hit, f"hit stats lost {key}"
    assert st_hit["kernelize.filter_reduce_sum"] == 1
    assert st_hit["kernelplan"]["routed"] == st_miss["kernelplan"]["routed"]
    # compile_ms in the stats dict is the REAL compile cost (cached in
    # the entry), even though WeldResult.compile_ms reports 0 on a hit
    assert st_hit["compile_ms"] == st_miss["compile_ms"] > 0


def test_cached_stats_returned_as_copy_mutation_cannot_poison():
    from repro.core import runtime

    runtime.clear_cache()
    obj, _ = _q6_like_obj(2779)
    st1: dict = {}
    Evaluate(obj, kernelize=True, collect_stats=st1)
    # poison attempt: mutate scalars AND nested containers of the
    # returned stats (dict(stats) used to share the nested dicts/lists
    # with the cache entry)
    st1["kernelplan"]["routed"]["fake_kernel"] = 99
    st1["kernelplan"]["costs"].append({"kernel": "fake"})
    st1["loops.after"] = -1
    st1["kernelize.matched"] = 0
    st1["bounds.admitted"] = False
    st1["bounds.builders"].append("fake builder line")
    st2: dict = {}
    r2 = Evaluate(obj, kernelize=True, collect_stats=st2)
    assert r2.from_cache is True
    assert "fake_kernel" not in st2["kernelplan"]["routed"]
    assert all(c.get("kernel") != "fake"
               for c in st2["kernelplan"]["costs"])
    assert st2["loops.after"] >= 0
    assert st2["kernelize.matched"] == 1
    assert st2["bounds.admitted"] is True
    assert "fake builder line" not in st2["bounds.builders"]
