"""Kernel planner golden tests: fused IR loops route onto the Pallas
kernel library, mismatches fall back to the jnp emitter unchanged, and
kernelized results agree with the generic backend."""
import numpy as np
import pytest

from repro.core import ir, macros as M, wtypes as wt
from repro.core import kernelplan as kp
from repro.core.lazy import Evaluate, NewWeldObject, build_program
from repro.core.passes import optimize

rng = np.random.RandomState(42)
N = 4096


def _ident(o):
    return ir.Ident(o.obj_id, o.weld_type())


def _q6_like_obj(n=N):
    """Fused filter+reduce: sum(price*disc where price < 0.5)."""
    price = NewWeldObject(rng.rand(n), None)
    disc = NewWeldObject(rng.rand(n), None)
    expr = M.filter_reduce(
        M.zip_map([_ident(price), _ident(disc)],
                  lambda p, d: ir.MakeStruct((p, d))),
        lambda x: ir.BinOp("<", ir.GetField(x, 0), ir.Literal(0.5, wt.F64)),
        "+",
        lambda x: ir.BinOp("*", ir.GetField(x, 0), ir.GetField(x, 1)),
    )
    obj = NewWeldObject([price, disc], expr)
    want = (np.asarray(price.data) * np.asarray(disc.data))[
        np.asarray(price.data) < 0.5
    ].sum()
    return obj, want


# ---------------------------------------------------------------------------
# golden: optimized programs are annotated with the expected KernelCall
# ---------------------------------------------------------------------------


def test_planner_annotates_q6_filter_reduce():
    obj, _ = _q6_like_obj()
    prog = build_program(obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    stats: dict = {}
    planned = kp.plan_kernels(opt, input_shapes=shapes, stats=stats)
    calls = [n for n in ir.walk(planned) if isinstance(n, ir.KernelCall)]
    assert stats["kernelize.matched"] == 1
    assert stats["kernelize.filter_reduce_sum"] == 1
    assert [c.kernel for c in calls] == ["filter_reduce_sum"]
    assert dict(calls[0].params)["has_pred"] is True


def test_planner_annotates_segment_reduce():
    """PageRank-style vecmerger scatter routes to segment_sum."""
    idxs = NewWeldObject(rng.randint(0, 100, N).astype(np.int64), None)
    vals = NewWeldObject(rng.rand(N), None)
    base = NewWeldObject(np.zeros(100), None)
    expr = M.scatter_add(_ident(base), _ident(idxs), _ident(vals))
    obj = NewWeldObject([base, idxs, vals], expr)
    prog = build_program(obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    stats: dict = {}
    planned = kp.plan_kernels(opt, input_shapes=shapes, stats=stats)
    assert stats.get("kernelize.vecmerger_segment_sum", 0) == 1
    assert any(isinstance(n, ir.KernelCall) for n in ir.walk(planned))


def test_planner_annotates_dict_groupby():
    keys = NewWeldObject(rng.randint(0, 32, N).astype(np.int64), None)
    vals = NewWeldObject(rng.rand(N), None)
    expr = M.groupby_agg(_ident(keys), _ident(vals), "+", capacity=64)
    obj = NewWeldObject([keys, vals], expr)
    prog = build_program(obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    stats: dict = {}
    kp.plan_kernels(opt, input_shapes=shapes, stats=stats)
    assert stats.get("kernelize.dict_group_sum", 0) == 1


def test_planner_annotates_matmul():
    from repro.frames import weldnp

    a = weldnp.array(rng.rand(32, 16))
    b = weldnp.array(rng.rand(16, 8))
    prog = build_program(a.dot(b).obj)
    shapes = {k: tuple(np.asarray(v[2]).shape) for k, v in prog.inputs.items()}
    opt = optimize(prog.expr, stats={}, input_shapes=shapes)
    stats: dict = {}
    kp.plan_kernels(opt, input_shapes=shapes, stats=stats)
    assert stats.get("kernelize.matmul", 0) == 1


# ---------------------------------------------------------------------------
# end-to-end parity: kernelized == jnp-only
# ---------------------------------------------------------------------------


def test_q6_kernelized_matches_jnp():
    obj, want = _q6_like_obj()
    st: dict = {}
    r1 = Evaluate(obj, kernelize=True, collect_stats=st)
    r0 = Evaluate(obj, kernelize=False)
    assert st["kernelize.filter_reduce_sum"] == 1
    np.testing.assert_allclose(r1.value, r0.value, rtol=1e-12)
    np.testing.assert_allclose(r1.value, want, rtol=1e-10)


def test_reduce_without_filter_kernelized():
    """Unconditional map+reduce (Black-Scholes shape) also routes."""
    x = rng.rand(N)
    xo = NewWeldObject(x, None)
    expr = M.reduce_(
        M.map_(_ident(xo), lambda v: ir.BinOp(
            "*", ir.UnaryOp("exp", v), ir.Literal(2.0, wt.F64))),
        "+",
    )
    obj = NewWeldObject([xo], expr)
    st: dict = {}
    r1 = Evaluate(obj, kernelize=True, collect_stats=st)
    assert st["kernelize.filter_reduce_sum"] == 1
    assert dict(
        [(k, v) for k, v in st.items() if k == "kernelize.matched"]
    )["kernelize.matched"] == 1
    np.testing.assert_allclose(r1.value, (np.exp(x) * 2.0).sum(), rtol=1e-10)


def test_segment_reduce_kernelized_matches_jnp():
    idxs = rng.randint(0, 100, N).astype(np.int64)
    vals = rng.rand(N)
    base = rng.rand(100)
    io = NewWeldObject(idxs, None)
    vo = NewWeldObject(vals, None)
    bo = NewWeldObject(base, None)
    expr = M.scatter_add(_ident(bo), _ident(io), _ident(vo))
    obj = NewWeldObject([bo, io, vo], expr)
    st: dict = {}
    r1 = np.asarray(Evaluate(obj, kernelize=True, collect_stats=st).value)
    r0 = np.asarray(Evaluate(obj, kernelize=False).value)
    assert st["kernelize.vecmerger_segment_sum"] == 1
    np.testing.assert_allclose(r1, r0, rtol=1e-12)
    want = base.copy()
    np.add.at(want, idxs, vals)
    np.testing.assert_allclose(r1, want, rtol=1e-10)


def test_groupby_kernelized_matches_jnp():
    from repro.frames import welddf

    state = rng.randint(0, 50, N).astype(np.int64)
    crime = rng.rand(N)
    df = welddf.DataFrame({"state": state, "crime": crime})
    st: dict = {}
    d1 = df.groupby_sum("state", "crime", capacity=64, kernelize=True,
                        collect_stats=st)
    d0 = df.groupby_sum("state", "crime", capacity=64, kernelize=False)
    assert st["kernelize.dict_group_sum"] == 1
    assert set(d1) == set(d0)
    for k in d0:
        np.testing.assert_allclose(d1[k], d0[k], rtol=1e-10)


def test_masked_groupby_kernelized_matches_jnp():
    from repro.frames import welddf

    state = rng.randint(0, 50, N).astype(np.int64)
    crime = rng.rand(N)
    df = welddf.DataFrame({"state": state, "crime": crime})
    fdf = df[df["crime"] > 0.5]
    st: dict = {}
    d1 = fdf.groupby_sum("state", "crime", capacity=64, kernelize=True,
                         collect_stats=st)
    d0 = fdf.groupby_sum("state", "crime", capacity=64, kernelize=False)
    assert st["kernelize.dict_group_sum"] == 1
    assert set(d1) == set(d0)
    for k in d0:
        np.testing.assert_allclose(d1[k], d0[k], rtol=1e-10)


def test_matmul_kernelized_matches_jnp():
    from repro.frames import weldnp

    A, B = rng.rand(48, 24), rng.rand(24, 16)
    wa, wb = weldnp.array(A), weldnp.array(B)
    st: dict = {}
    got = np.asarray(wa.dot(wb).evaluate(kernelize=True, collect_stats=st))
    assert st["kernelize.matmul"] == 1
    np.testing.assert_allclose(got.reshape(48, 16), A @ B, rtol=1e-12)


def test_map_chain_kernelized_matches_jnp():
    from repro.frames import weldnp

    x = rng.rand(N)
    wx = weldnp.array(x)
    y = weldnp.exp(wx * 2.0) + 1.0
    st: dict = {}
    got = np.asarray(y.evaluate(kernelize=True, collect_stats=st))
    assert st["kernelize.map_elementwise"] == 1
    np.testing.assert_allclose(got, np.exp(x * 2.0) + 1.0, rtol=1e-12)


# ---------------------------------------------------------------------------
# fallback: mismatches lower exactly as before
# ---------------------------------------------------------------------------


def test_non_plus_reduce_falls_back():
    """max-reduce has no kernel; planner must leave it to the emitter."""
    x = rng.rand(N)
    xo = NewWeldObject(x, None)
    expr = M.reduce_(_ident(xo), "max")
    obj = NewWeldObject([xo], expr)
    st: dict = {}
    r1 = Evaluate(obj, kernelize=True, collect_stats=st)
    assert st["kernelize.matched"] == 0
    np.testing.assert_allclose(r1.value, x.max(), rtol=1e-12)


def test_big_capacity_groupby_falls_back():
    """capacity beyond the VMEM tile bound must not route."""
    from repro.frames import welddf

    state = rng.randint(0, 50, N).astype(np.int64)
    crime = rng.rand(N)
    df = welddf.DataFrame({"state": state, "crime": crime})
    st: dict = {}
    d1 = df.groupby_sum("state", "crime", capacity=1 << 17, kernelize=True,
                        collect_stats=st)
    assert st["kernelize.matched"] == 0
    d0 = df.groupby_sum("state", "crime", capacity=1 << 17, kernelize=False)
    assert set(d1) == set(d0)


def test_default_capacity_groupby_routes():
    """The frames' default capacity (4096) must fit the kernel tile."""
    from repro.frames import welddf

    state = rng.randint(0, 50, N).astype(np.int64)
    crime = rng.rand(N)
    df = welddf.DataFrame({"state": state, "crime": crime})
    st: dict = {}
    d1 = df.groupby_sum("state", "crime", kernelize=True, collect_stats=st)
    assert st["kernelize.dict_group_sum"] == 1
    d0 = df.groupby_sum("state", "crime", kernelize=False)
    assert set(d1) == set(d0)
    for k in d0:
        np.testing.assert_allclose(d1[k], d0[k], rtol=1e-10)


def test_out_of_range_keys_raise_not_drop():
    """Keys outside [0, capacity) can't be represented by the dense-key
    route; decoding must raise instead of silently dropping rows the
    generic path would keep."""
    from repro.frames import welddf

    key = np.array([100, 100, 1, 2], dtype=np.int64)
    val = np.array([1.0, 2.0, 3.0, 4.0])
    df = welddf.DataFrame({"k": key, "v": val})
    d0 = df.groupby_sum("k", "v", capacity=64, kernelize=False)
    assert d0 == {1: 3.0, 2: 4.0, 100: 3.0}
    with pytest.raises(RuntimeError, match="outside \\[0, capacity\\)"):
        df.groupby_sum("k", "v", capacity=64, kernelize=True)


def test_float_key_groupby_falls_back():
    from repro.frames import welddf

    key = rng.rand(N)  # float keys: no dense-int routing
    val = rng.rand(N)
    df = welddf.DataFrame({"k": key, "v": val})
    st: dict = {}
    df.groupby_sum("k", "v", capacity=8192, kernelize=True, collect_stats=st)
    assert st["kernelize.matched"] == 0


def test_kernelize_false_is_default_and_identical():
    """No knob -> no planning; stats carry no kernelize keys."""
    obj, want = _q6_like_obj()
    st: dict = {}
    r = Evaluate(obj, collect_stats=st)
    assert not any(k.startswith("kernelize") for k in st)
    np.testing.assert_allclose(r.value, want, rtol=1e-10)


# ---------------------------------------------------------------------------
# impl resolution: interpret (Pallas body on CPU) vs ref oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pattern", ["q6", "mapchain"])
def test_interpret_matches_ref(pattern):
    if pattern == "q6":
        obj, _ = _q6_like_obj(512)
        ri = Evaluate(obj, kernelize=True, kernel_impl="interpret")
        rr = Evaluate(obj, kernelize=True, kernel_impl="ref")
        np.testing.assert_allclose(ri.value, rr.value, rtol=1e-12)
    else:
        from repro.frames import weldnp

        x = rng.rand(512)
        wx = weldnp.array(x)
        y = weldnp.exp(wx * 2.0) + 1.0
        gi = np.asarray(y.evaluate(kernelize=True, kernel_impl="interpret"))
        gr = np.asarray(y.evaluate(kernelize=True, kernel_impl="ref"))
        np.testing.assert_allclose(gi, gr, rtol=1e-12)


def test_overflowed_dict_lookup_is_poisoned_not_plausible():
    """In-IR Lookup into an overflowed kernelized dict must not return a
    plausible-but-wrong number: float sums are NaN-poisoned and KeyExists
    sees no keys (host decode raises separately)."""
    keys = NewWeldObject(np.array([100, 1, 2], dtype=np.int64), None)
    vals = NewWeldObject(np.array([3.0, 3.0, 4.0]), None)
    d = M.groupby_agg(_ident(keys), _ident(vals), "+", capacity=8)
    obj = NewWeldObject([keys, vals], ir.Lookup(d, ir.Literal(2, wt.I64)))
    g0 = Evaluate(obj, kernelize=False).value
    assert g0 == 4.0
    assert np.isnan(Evaluate(obj, kernelize=True).value)
    d2 = M.groupby_agg(_ident(keys), _ident(vals), "+", capacity=8)
    obj2 = NewWeldObject([keys, vals],
                         ir.KeyExists(d2, ir.Literal(1, wt.I64)))
    assert not bool(Evaluate(obj2, kernelize=True).value)


def test_unregister_invalidates_compile_cache():
    """register/unregister is the ablation knob; a cached kernelized
    executable must not survive a registry change."""
    from repro.frames import welddf

    df = welddf.DataFrame({"k": np.array([1, 2, 2], dtype=np.int64),
                           "v": np.array([1.0, 2.0, 3.0])})
    st: dict = {}
    df.groupby_sum("k", "v", capacity=16, kernelize=True, collect_stats=st)
    assert st["kernelize.dict_group_sum"] == 1
    spec = kp.get("dict_group_sum")
    kp.unregister("dict_group_sum")
    try:
        st2: dict = {}
        d = df.groupby_sum("k", "v", capacity=16, kernelize=True,
                           collect_stats=st2)
        assert st2.get("kernelize.dict_group_sum", 0) == 0
        assert d == {1: 1.0, 2: 5.0}
    finally:
        kp.register(spec)


def test_program_evaluate_threads_kernelize():
    """lazy.Program.evaluate exposes the knob and the planner stats."""
    obj, want = _q6_like_obj()
    prog = build_program(obj)
    value, compile_ms, from_cache, stats = prog.evaluate(kernelize=True)
    assert stats["kernelize.filter_reduce_sum"] == 1
    np.testing.assert_allclose(np.asarray(value), want, rtol=1e-10)
    v0, *_ = prog.evaluate(kernelize=False)
    np.testing.assert_allclose(np.asarray(value), np.asarray(v0), rtol=1e-12)


def test_registry_describes_all_kernels():
    names = {s.name for s in kp.all_specs()}
    assert {"filter_reduce_sum", "vecmerger_segment_sum", "dict_group_sum",
            "matmul", "matvec", "map_elementwise"} <= names
    text = kp.describe()
    assert "repro.kernels.ops" in text
