"""Library-integration tests (paper §6): weldnp / welddf / weldrel /
weldflow agree with native NumPy on every ported operator and compose
across libraries into fused programs."""
import numpy as np
import pytest

from repro.core import runtime
from repro.frames import welddf, weldflow, weldnp, weldrel

rng = np.random.RandomState(42)


# ---------------------------------------------------------------------------
# weldnp
# ---------------------------------------------------------------------------


class TestWeldNP:
    def test_elementwise_chain(self):
        a = rng.rand(1000)
        b = rng.rand(1000)
        wa, wb = weldnp.array(a), weldnp.array(b)
        out = (wa * 2.0 + wb / 3.0 - 1.0).to_numpy()
        np.testing.assert_allclose(out, a * 2.0 + b / 3.0 - 1.0, rtol=1e-12)

    def test_fusion_collapses_chain(self):
        runtime.clear_cache()
        a = weldnp.array(rng.rand(100))
        stats = {}
        out = ((a + 1.0) * 2.0 - 0.5)
        res = out.obj.evaluate()
        st = {}
        from repro.core.lazy import Evaluate
        Evaluate(((a + 1.0) * 2.0 - 0.5).obj, collect_stats=st)
        assert st["loops.after"] == 1

    def test_unary_math(self):
        a = rng.rand(500) + 0.5
        wa = weldnp.array(a)
        np.testing.assert_allclose(weldnp.exp(wa).to_numpy(), np.exp(a), rtol=1e-12)
        np.testing.assert_allclose(weldnp.log(wa).to_numpy(), np.log(a), rtol=1e-12)
        np.testing.assert_allclose(weldnp.sqrt(wa).to_numpy(), np.sqrt(a), rtol=1e-12)
        import math
        np.testing.assert_allclose(
            weldnp.erf(wa).to_numpy(), np.vectorize(math.erf)(a), rtol=1e-10
        )

    def test_reductions(self):
        a = rng.rand(256)
        wa = weldnp.array(a)
        assert abs(wa.sum().item() - a.sum()) < 1e-9
        assert abs(wa.min().item() - a.min()) < 1e-12
        assert abs(wa.max().item() - a.max()) < 1e-12

    def test_scalar_broadcast_and_reverse_ops(self):
        a = rng.rand(64)
        wa = weldnp.array(a)
        np.testing.assert_allclose((2.0 - wa).to_numpy(), 2.0 - a)
        np.testing.assert_allclose((1.0 / (wa + 1.0)).to_numpy(), 1.0 / (a + 1.0))

    def test_dot_1d(self):
        a, b = rng.rand(128), rng.rand(128)
        got = weldnp.dot(weldnp.array(a), weldnp.array(b)).item()
        assert abs(got - np.dot(a, b)) < 1e-9

    def test_matvec(self):
        m, v = rng.rand(32, 16), rng.rand(16)
        got = weldnp.array(m).dot(weldnp.array(v)).to_numpy()
        np.testing.assert_allclose(got, m @ v, rtol=1e-12)

    def test_matmul(self):
        a, b = rng.rand(8, 4), rng.rand(4, 6)
        got = weldnp.array(a).dot(weldnp.array(b)).to_numpy()
        np.testing.assert_allclose(got, a @ b, rtol=1e-12)

    def test_where(self):
        c = rng.rand(100)
        wa = weldnp.array(c)
        got = weldnp.where(wa > 0.5, wa * 2.0, -1.0).to_numpy()
        np.testing.assert_allclose(got, np.where(c > 0.5, c * 2.0, -1.0))

    def test_astype(self):
        a = rng.rand(32) * 10
        got = weldnp.array(a).astype(np.int64).to_numpy()
        np.testing.assert_array_equal(got, a.astype(np.int64))

    def test_int_comparison_dtype(self):
        a = np.arange(10, dtype=np.int64)
        mask = (weldnp.array(a) > 4).to_numpy()
        assert mask.dtype == np.bool_
        np.testing.assert_array_equal(mask, a > 4)

    def test_eager_mode_matches(self):
        a = rng.rand(100)
        lazy = (weldnp.array(a) * 3.0 + 1.0).sum().item()
        eager = (weldnp.array(a, eager=True) * 3.0 + 1.0).sum()._eager.item()
        assert abs(lazy - eager) < 1e-9


# ---------------------------------------------------------------------------
# welddf
# ---------------------------------------------------------------------------


class TestWeldDF:
    def _df(self, eager=False):
        pop = rng.randint(0, 1_000_000, 20_000).astype(np.int64)
        crime = rng.rand(20_000)
        return welddf.DataFrame(
            {"population": pop, "crime": crime}, eager=eager
        ), pop, crime

    def test_listing7_filter_sum(self):
        df, pop, _ = self._df()
        got = df[df["population"] > 500_000].agg_sum("population").item()
        assert got == pop[pop > 500_000].sum()

    def test_filtered_column_materialization(self):
        df, pop, _ = self._df()
        got = df[df["population"] > 900_000]["population"].to_numpy()
        np.testing.assert_array_equal(np.sort(got), np.sort(pop[pop > 900_000]))

    def test_count(self):
        df, pop, _ = self._df()
        assert df[df["population"] > 500_000].count().item() == \
            int((pop > 500_000).sum())
        assert df.count().item() == len(pop)

    def test_cross_library_crime_index(self):
        """The paper's crime-index workload: welddf filter + weldnp math."""
        df, pop, crime = self._df()
        big = df[df["population"] > 500_000]
        idx = big["population"] * 0.1 + big["crime"] * 2.0
        got = idx.sum().item()
        m = pop > 500_000
        want = (pop[m] * 0.1 + crime[m] * 2.0).sum()
        assert abs(got - want) < 1e-6 * abs(want)

    def test_groupby_sum(self):
        keys = rng.randint(0, 5, 5000).astype(np.int64)
        vals = rng.rand(5000)
        df = welddf.DataFrame({"k": keys, "v": vals})
        got = df.groupby_sum("k", "v", capacity=16)
        for k in range(5):
            assert abs(got[k] - vals[keys == k].sum()) < 1e-8

    def test_groupby_sum_filtered(self):
        keys = rng.randint(0, 4, 4000).astype(np.int64)
        vals = rng.rand(4000)
        df = welddf.DataFrame({"k": keys, "v": vals})
        fdf = df[df["v"] > 0.5]
        got = fdf.groupby_sum("k", "v", capacity=16)
        for k in range(4):
            m = (keys == k) & (vals > 0.5)
            assert abs(got[k] - vals[m].sum()) < 1e-8

    def test_unique(self):
        keys = rng.randint(0, 7, 1000).astype(np.int64)
        df = welddf.DataFrame({"k": keys})
        np.testing.assert_array_equal(df.unique("k", capacity=32), np.unique(keys))

    def test_slice_code(self):
        zips = np.array([9_411_023, 94_110, 612, 12_345_678], dtype=np.int64)
        df = welddf.DataFrame({"zip": zips})
        got = df.slice_code("zip", 5).to_numpy()
        np.testing.assert_array_equal(got, np.array([94110, 94110, 612, 12345]))

    def test_eager_paths_match(self):
        df, pop, crime = self._df()
        dfe = welddf.DataFrame({"population": pop.copy(), "crime": crime.copy()},
                               eager=True)
        lazy = df[df["population"] > 500_000].agg_sum("population").item()
        eager = dfe[dfe["population"] > 500_000].agg_sum("population")._eager.item()
        assert lazy == eager


# ---------------------------------------------------------------------------
# weldrel (TPC-H shapes)
# ---------------------------------------------------------------------------


class TestWeldRel:
    def _lineitem(self, n=20_000):
        return {
            "ship": rng.randint(0, 2557, n).astype(np.int64),
            "disc": rng.uniform(0, 0.1, n),
            "qty": rng.uniform(1, 50, n),
            "price": rng.uniform(100, 10_000, n),
            "tax": rng.uniform(0, 0.08, n),
            "rf": rng.randint(0, 3, n).astype(np.int64),
            "ls": rng.randint(0, 2, n).astype(np.int64),
        }

    def test_q6(self):
        cols = self._lineitem()
        t = weldrel.Table(cols)
        q = weldrel.Query(t).filter(
            (t.col("ship") >= 365) & (t.col("ship") < 730)
            & (t.col("disc") >= 0.05) & (t.col("disc") <= 0.07)
            & (t.col("qty") < 24.0)
        )
        got = q.agg({"rev": (t.col("price") * t.col("disc"), "+")})["rev"]
        m = (
            (cols["ship"] >= 365) & (cols["ship"] < 730)
            & (cols["disc"] >= 0.05) & (cols["disc"] <= 0.07)
            & (cols["qty"] < 24.0)
        )
        want = (cols["price"] * cols["disc"])[m].sum()
        assert abs(got - want) < 1e-6 * max(abs(want), 1)

    def test_q1_grouped(self):
        cols = self._lineitem()
        t = weldrel.Table(cols)
        disc_price = t.col("price") * (1.0 - t.col("disc"))
        charge = disc_price * (1.0 + t.col("tax"))
        q = weldrel.Query(t).filter(t.col("ship") <= 2000)
        out = q.group_agg(
            [t.col("rf"), t.col("ls")],
            {
                "sum_qty": (t.col("qty"), "+"),
                "sum_base": (t.col("price"), "+"),
                "sum_disc_price": (disc_price, "+"),
                "sum_charge": (charge, "+"),
            },
            capacity=64,
        )
        m = cols["ship"] <= 2000
        for rf in range(3):
            for ls in range(2):
                g = m & (cols["rf"] == rf) & (cols["ls"] == ls)
                if not g.any():
                    continue
                sq, sb, sdp, sc, cnt = out[(rf, ls)]
                assert abs(sq - cols["qty"][g].sum()) < 1e-6 * sq
                assert abs(sb - cols["price"][g].sum()) < 1e-6 * sb
                dp = (cols["price"] * (1 - cols["disc"]))[g].sum()
                assert abs(sdp - dp) < 1e-6 * dp
                assert cnt == int(g.sum())

    def test_eager_agg_matches(self):
        cols = self._lineitem(2000)
        t = weldrel.Table(cols, eager=True)
        tl = weldrel.Table(cols)
        qe = weldrel.Query(t).filter(t.col("qty") < 24.0)
        got_e = qe.agg({"rev": (t.col("price") * t.col("disc"), "+")})["rev"]
        ql = weldrel.Query(tl).filter(tl.col("qty") < 24.0)
        got_l = ql.agg({"rev": (tl.col("price") * tl.col("disc"), "+")})["rev"]
        assert abs(got_e - got_l) < 1e-6 * abs(got_e)


# ---------------------------------------------------------------------------
# weldflow
# ---------------------------------------------------------------------------


class TestWeldFlow:
    def _graph(self):
        m = rng.rand(500, 20)
        w = rng.rand(20)
        x = weldflow.placeholder()
        logits = weldflow.matvec(x, weldflow.constant(w)) + 0.25
        probs = weldflow.sigmoid(logits)
        loss = weldflow.reduce_mean(weldflow.log(probs))
        return loss, {x: m}, m, w

    def test_three_modes_agree(self):
        loss, feed, m, w = self._graph()
        want = np.mean(np.log(1 / (1 + np.exp(-(m @ w + 0.25)))))
        for mode in ("native", "xla", "weld"):
            got = weldflow.Session(mode).run(loss, feed)
            assert abs(float(got) - want) < 1e-9, mode

    def test_transformer_merges_whole_graph(self):
        loss, feed, _, _ = self._graph()
        obj, merged = weldflow.transform_graph(loss, feed)
        assert merged >= 5  # matvec, add, sigmoid, log, mean
