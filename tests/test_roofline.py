"""Roofline machinery tests: HLO collective parsing, cost normalization,
term computation."""
import numpy as np
import pytest

from repro.roofline.analysis import (
    HW_V5E, collective_bytes_from_hlo, extract_cost, roofline_terms,
)

HLO_SAMPLE = """
HloModule jit_f

%add { ... }

ENTRY %main (p0: f32[128,512]) -> f32[] {
  %all-reduce = f32[128,512]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %all-gather.5 = f32[2048,512]{1,0} all-gather(%y), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}, use_global_device_ids=true
  %reduce-scatter.1 = bf16[16,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}, to_apply=%add
  %cp = f32[256]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  %ag2 = f32[64]{0} all-gather-start(%q), channel_id=5, replica_groups=[2,4]<=[8], dimensions={0}
  %ag2d = f32[64]{0} all-gather-done(%ag2)
  %a2a = f32[32,8]{1,0} all-to-all(%r), channel_id=6, replica_groups=[2,4]<=[8], dimensions={0}
}
"""


def test_collective_parser_kinds_and_bytes():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    # all-reduce: operand == result = 128*512*4
    assert out["all-reduce"] == 128 * 512 * 4
    # all-gather: operand = result / participants(4); two of them
    assert out["all-gather"] == (2048 * 512 * 4) // 4 + (64 * 4) // 4
    # reduce-scatter: operand = result * participants(8), bf16
    assert out["reduce-scatter"] == 16 * 64 * 2 * 8
    # collective-permute & all-to-all: operand == result
    assert out["collective-permute"] == 256 * 4
    assert out["all-to-all"] == 32 * 8 * 4
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))


def test_collective_parser_skips_done_ops():
    out = collective_bytes_from_hlo(
        "%x = f32[64]{0} all-gather-done(%ag2)\n")
    assert out["total"] == 0


def test_extract_cost_normalizes():
    assert extract_cost({"flops": 10.0, "bytes accessed": 5.0}) == \
        {"flops": 10.0, "bytes": 5.0}
    # already-normalized dicts pass through (idempotent)
    assert extract_cost({"flops": 10.0, "bytes": 5.0}) == \
        {"flops": 10.0, "bytes": 5.0}
    # per-operand byte keys summed when the aggregate key is missing
    c = extract_cost({"flops": 1.0, "bytes accessed0{}": 3.0,
                      "bytes accessed1{}": 4.0})
    assert c["bytes"] == 7.0


def test_roofline_terms_and_bottleneck():
    cost = {"flops": HW_V5E["peak_flops_bf16"],          # 1 s of compute
            "bytes": HW_V5E["hbm_bw"] / 2}               # 0.5 s of memory
    out = roofline_terms(cost, int(HW_V5E["ici_bw"] / 4))  # 0.25 s of comms
    assert out["bottleneck"] == "compute"
    assert abs(out["t_compute_s"] - 1.0) < 1e-9
    assert abs(out["t_memory_s"] - 0.5) < 1e-9
    assert abs(out["t_collective_s"] - 0.25) < 1e-9
    assert out["bound_s"] == out["t_compute_s"]


def test_roofline_collective_bound():
    cost = {"flops": 1.0, "bytes": 1.0}
    out = roofline_terms(cost, int(HW_V5E["ici_bw"]))    # 1 s of comms
    assert out["bottleneck"] == "collective"


def test_cost_while_loop_motivation():
    """Documents WHY the dry-run extrapolates: XLA counts while bodies
    once (if this ever changes, the extrapolation should be revisited)."""
    import jax
    import jax.numpy as jnp

    def mk(n_layers):
        def f(x, w):
            def body(h, wl):
                return jnp.tanh(h @ wl), None
            h, _ = jax.lax.scan(body, x, w)
            return h.sum()
        xs = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        ws = jax.ShapeDtypeStruct((n_layers, 16, 16), jnp.float32)
        ca = jax.jit(f).lower(xs, ws).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0]
        return ca["flops"]

    assert mk(2) == mk(8)
