"""Weld IR unit tests: types, typecheck, linearity, canonical keys."""
import numpy as np
import pytest

from repro.core import ir, macros as M, wtypes as wt
from repro.core.interp import interpret


def test_scalar_types():
    assert str(wt.Vec(wt.I64)) == "vec[i64]"
    assert str(wt.DictType(wt.I64, wt.F64)) == "dict[i64,f64]"
    assert wt.Merger(wt.F64, "+").result_type() == wt.F64
    assert wt.VecBuilder(wt.I32).result_type() == wt.Vec(wt.I32)
    assert wt.DictMerger(wt.I64, wt.F64).result_type() == wt.DictType(wt.I64, wt.F64)
    assert wt.GroupBuilder(wt.I64, wt.F64).result_type() == \
        wt.DictType(wt.I64, wt.Vec(wt.F64))


def test_merge_identity():
    assert wt.merge_identity("+", wt.F64) == 0.0
    assert wt.merge_identity("*", wt.I64) == 1
    assert wt.merge_identity("min", wt.I32) == np.iinfo(np.int32).max
    assert wt.merge_identity("max", wt.F32) < -1e38


def test_typeof_listing1():
    b1 = ir.NewBuilder(wt.VecBuilder(wt.I64))
    b2 = ir.Merge(b1, ir.Literal(5, wt.I64))
    assert ir.typeof(b2) == wt.VecBuilder(wt.I64)
    assert ir.typeof(ir.Result(b2)) == wt.Vec(wt.I64)


def test_typeof_struct_of_builders():
    s = ir.MakeStruct((
        ir.NewBuilder(wt.VecBuilder(wt.I64)),
        ir.NewBuilder(wt.Merger(wt.I64, "+")),
    ))
    t = ir.typeof(s)
    assert isinstance(t, wt.StructBuilder)
    assert ir.typeof(ir.Result(s)) == wt.Struct((wt.Vec(wt.I64), wt.I64))


def test_typeof_rejects_mismatch():
    with pytest.raises(wt.WeldTypeError):
        ir.typeof(ir.BinOp("+", ir.Literal(1, wt.I64), ir.Literal(1.0, wt.F64)))
    with pytest.raises(wt.WeldTypeError):
        ir.typeof(ir.Merge(ir.NewBuilder(wt.Merger(wt.I64, "+")),
                           ir.Literal(1.0, wt.F64)))


def test_for_typecheck():
    v = ir.MakeVec((ir.Literal(1, wt.I64), ir.Literal(2, wt.I64)), wt.I64)
    loop = M.map_(v, lambda x: ir.BinOp("+", x, ir.Literal(1, wt.I64)))
    assert ir.typeof(loop) == wt.Vec(wt.I64)
    assert interpret(loop) == [2, 3]


def test_linearity_ok():
    v = ir.MakeVec((ir.Literal(1, wt.I64),), wt.I64)
    e = M.reduce_(v, "+")
    ir.check_linearity(e)  # should not raise


def test_linearity_violation():
    bt = wt.Merger(wt.I64, "+")
    b = ir.Ident("b0", bt)
    # consume b twice on one path: merge(b, ...) and merge(b, ...) combined
    bad = ir.Let(
        "b0", ir.NewBuilder(bt),
        ir.MakeStruct((ir.Merge(b, ir.Literal(1, wt.I64)),
                       ir.Merge(b, ir.Literal(2, wt.I64)))),
    )
    with pytest.raises(wt.WeldTypeError):
        ir.check_linearity(bad)


def test_linearity_if_paths_ok():
    """Each control path consumes the builder once (paper's rule)."""
    bt = wt.Merger(wt.I64, "+")
    b = ir.Ident("b1", bt)
    e = ir.Let(
        "b1", ir.NewBuilder(bt),
        ir.If(ir.Literal(True, wt.Bool),
              ir.Merge(b, ir.Literal(1, wt.I64)), b),
    )
    ir.check_linearity(e)


def test_canon_key_alpha_invariant():
    v = ir.MakeVec((ir.Literal(1, wt.I64),), wt.I64)
    a = M.map_(v, lambda x: ir.BinOp("*", x, ir.Literal(3, wt.I64)))
    b = M.map_(v, lambda x: ir.BinOp("*", x, ir.Literal(3, wt.I64)))
    assert a is not b
    assert ir.canon_key(a) == ir.canon_key(b)
    c = M.map_(v, lambda x: ir.BinOp("*", x, ir.Literal(4, wt.I64)))
    assert ir.canon_key(a) != ir.canon_key(c)


def test_canon_key_iter_fields_disambiguated():
    v = ir.Ident("v", wt.Vec(wt.I64))
    lit = ir.Literal(2, wt.I64)
    i1 = ir.Iter(v, start=lit)
    i2 = ir.Iter(v, end=lit)
    assert ir.canon_key(i1) != ir.canon_key(i2)


def test_substitute_and_free_vars():
    x = ir.Ident("x", wt.I64)
    e = ir.BinOp("+", x, ir.Literal(1, wt.I64))
    assert set(ir.free_vars(e)) == {"x"}
    e2 = ir.substitute(e, {"x": ir.Literal(41, wt.I64)})
    assert interpret(e2) == 42
    # binder shadowing
    e3 = ir.Let("x", ir.Literal(5, wt.I64), x)
    e4 = ir.substitute(e3, {"x": ir.Literal(9, wt.I64)})
    assert interpret(e4) == 5


def test_rename_binders_preserves_semantics():
    v = ir.MakeVec((ir.Literal(2, wt.I64), ir.Literal(3, wt.I64)), wt.I64)
    e = M.reduce_(v, "+", fn=lambda x: ir.BinOp("*", x, x))
    r = ir.rename_binders(e)
    assert interpret(e) == interpret(r) == 13
    assert ir.canon_key(e) == ir.canon_key(r)


def test_pretty_roundtrip_smoke():
    v = ir.MakeVec((ir.Literal(1, wt.I64),), wt.I64)
    e = M.filter_(v, lambda x: ir.BinOp(">", x, ir.Literal(0, wt.I64)))
    s = str(e)
    assert "for(" in s and "vecbuilder" in s


def test_interp_strided_iter():
    data = list(range(10))
    v = ir.Ident("v", wt.Vec(wt.I64))
    loop = ir.Result(ir.For(
        (ir.Iter(v, start=ir.Literal(1, wt.I64), end=ir.Literal(9, wt.I64),
                 stride=ir.Literal(2, wt.I64)),),
        ir.NewBuilder(wt.VecBuilder(wt.I64)),
        M._lam3(wt.VecBuilder(wt.I64), wt.I64, lambda b, i, x: ir.Merge(b, x)),
    ))
    assert interpret(loop, {"v": data}) == [1, 3, 5, 7]


def test_grouplookup_typeof_pretty_interp():
    dt = wt.DictType(wt.I64, wt.Vec(wt.I64))
    d = ir.Ident("d", dt)
    e = ir.GroupLookup(d, ir.Literal(3, wt.I64))
    assert ir.typeof(e) == wt.Vec(wt.I64)
    assert "grouplookup(d, 3)" in str(e)
    assert interpret(e, {"d": {3: [7, 8]}}) == [7, 8]
    assert interpret(e, {"d": {5: [1]}}) == []  # miss -> EMPTY vector
    with pytest.raises(wt.WeldTypeError):
        ir.typeof(ir.GroupLookup(d, ir.Literal(0.5, wt.F64)))
    with pytest.raises(wt.WeldTypeError):
        ir.typeof(ir.GroupLookup(
            ir.Ident("v", wt.DictType(wt.I64, wt.F64)),
            ir.Literal(1, wt.I64)))


def test_grouplookup_expansion_interp_oracle():
    """The canonical m:n expansion loop under the reference interpreter:
    probe rows fan out to (row, match) pairs in build order."""
    rk = ir.Ident("rk", wt.Vec(wt.I64))
    gb = wt.GroupBuilder(wt.I64, wt.I64)
    b = ir.Ident("b0", gb)
    i = ir.Ident("i0", wt.I64)
    x = ir.Ident("x0", wt.I64)
    build = ir.Result(ir.For(
        (ir.Iter(rk),),
        ir.NewBuilder(gb, arg=ir.Literal(8, wt.I64)),
        ir.Lambda((b, i, x), ir.Merge(b, ir.MakeStruct((x, i)))),
    ))
    d = interpret(build, {"rk": [5, 3, 5, 5]})
    assert d == {5: [0, 2, 3], 3: [1]}
    sbt = wt.StructBuilder((wt.VecBuilder(wt.I64), wt.VecBuilder(wt.I64)))
    lk = ir.Ident("lk", wt.Vec(wt.I64))
    did = ir.Ident("d", wt.DictType(wt.I64, wt.Vec(wt.I64)))
    b2 = ir.Ident("b2", sbt)
    i2 = ir.Ident("i2", wt.I64)
    x2 = ir.Ident("x2", wt.I64)
    bi = ir.Ident("bi", sbt)
    ii = ir.Ident("ii", wt.I64)
    ri = ir.Ident("ri", wt.I64)
    probe = ir.Result(ir.For(
        (ir.Iter(lk),),
        ir.MakeStruct((ir.NewBuilder(wt.VecBuilder(wt.I64)),
                       ir.NewBuilder(wt.VecBuilder(wt.I64)))),
        ir.Lambda((b2, i2, x2), ir.For(
            (ir.Iter(ir.GroupLookup(did, x2)),),
            b2,
            ir.Lambda((bi, ii, ri), ir.MakeStruct((
                ir.Merge(ir.GetField(bi, 0), x2),
                ir.Merge(ir.GetField(bi, 1), ri),
            ))),
        )),
    ))
    keys, rows = interpret(probe, {"lk": [5, 9, 3], "d": d})
    assert keys == [5, 5, 5, 3]
    assert rows == [0, 2, 3, 1]
