"""Python UDF translator tests (paper §4.4 / Fig 6d)."""
import math

import numpy as np
import pytest

from repro.core import ir, macros as M, wtypes as wt
from repro.core.interp import interpret
from repro.core.lazy import Evaluate, NewWeldObject
from repro.frames.pyudf import WeldUDF, parse_signature, weld

A0, A1 = 0.1, 2.0  # module-level closure constants


@weld("(f64) => f64")
def linear_model(x):
    return x * A0 + A1


@weld("(f64) => f64")
def squash(x):
    return math.exp(x) / (1.0 + math.exp(x))


@weld("(f64) => f64")
def piecewise(x):
    return math.sqrt(x) * 2.0 + 1.0 if x > 0.5 else 0.0


@weld("(i64) => bool")
def is_even(x):
    return x % 2 == 0


def test_parse_signature():
    params, ret = parse_signature("(f64, i64) => bool")
    assert params == [wt.F64, wt.I64] and ret == wt.Bool


def test_udf_still_callable_in_python():
    assert linear_model(10.0) == 10.0 * A0 + A1


def test_udf_to_ir_scalar():
    e = linear_model.to_ir([ir.Literal(3.0, wt.F64)])
    assert abs(interpret(e) - (3.0 * A0 + A1)) < 1e-12


def test_udf_closure_constants():
    e = piecewise.to_ir([ir.Literal(0.81, wt.F64)])
    assert abs(interpret(e) - (math.sqrt(0.81) * 2 + 1)) < 1e-12
    e2 = piecewise.to_ir([ir.Literal(0.25, wt.F64)])
    assert interpret(e2) == 0.0


def test_udf_bool():
    assert interpret(is_even.to_ir([ir.Literal(4, wt.I64)])) is True
    assert interpret(is_even.to_ir([ir.Literal(5, wt.I64)])) is False


def test_udf_in_query_fused():
    """Fig 6d: UDF mapped over rows, co-optimized with the reduction."""
    rng = np.random.RandomState(0)
    data = rng.rand(10_000)
    d = NewWeldObject(data, None)
    did = ir.Ident(d.obj_id, d.weld_type())
    mapped = M.map_(did, lambda x: linear_model.to_ir([x]))
    mean_expr = ir.BinOp(
        "/",
        M.reduce_(mapped, "+"),
        ir.Cast(ir.Len(did), wt.F64),
    )
    stats = {}
    out = Evaluate(NewWeldObject([d], mean_expr), collect_stats=stats).value
    want = (data * A0 + A1).mean()
    assert abs(out - want) < 1e-9
    assert stats["loops.after"] == 1  # UDF fused into the aggregation pass


def test_udf_rejects_statements():
    with pytest.raises(ValueError):
        @weld("(f64) => f64")
        def two_statements(x):
            y = x + 1
            return y
